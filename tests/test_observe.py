"""Observer subsystem + headroom-driven mixed-precision search.

Covers the full loop: calibration observers -> per-site report ->
certificate-exact P_I search (bit-identical perplexity at a strictly
tighter global accumulator budget) -> v2 mixed-precision artifact
(strict loading, per-site validate_datapath) -> paged serving with
saturation counters (structurally transparent when disabled) and
calibrated static KV page scales.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import save_pytree
from repro.configs import get_config, get_smoke
from repro.core import PTQConfig, accumulator_range, certify, min_feasible_p_bits
from repro.data import DataConfig, TokenBatcher
from repro.models.transformer import init_model
from repro.quant import calibrate_and_quantize
from repro.quant.observe import (
    MixedPrecisionPlan,
    SaturationCounters,
    apply_plan,
    collect_observations,
    observe_kv_ranges,
    plan_accumulator_bits,
    search_kv_bits,
    search_plan,
)
from repro.quant.pipeline import quantized_ppl
from repro.quant.serve_packed import (
    export_quantized_artifact,
    load_flat_artifact,
    pack_decode_params,
    packed_params_from_artifact,
    plan_expected_specs,
    serving_params_from_quantized,
)
from repro.quant.spec import (
    DatapathMismatchError,
    DatapathSpec,
    site_key_for_path,
    validate_datapath,
)
from repro.serving import PagedConfig, PagedEngine, SamplerConfig

GREEDY = SamplerConfig(temperature=0.0)

#: conservative uniform register: the per-site slack below it is what the
#: search reclaims (constrained GPFQ at a tight register shapes codes to
#: *fill* it, leaving nothing to search — see docs/mixed_precision.md)
P_UNIFORM = 20


@pytest.fixture(scope="module")
def calibrated():
    cfg = get_config("tiny-lm-xs")
    params = init_model(jax.random.key(0), cfg)
    data = TokenBatcher(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=2))
    calib = [data.batch(100 + i) for i in range(2)]
    evalb = list(data.eval_batches(2))
    ptq = PTQConfig(w_bits=4, act_bits=8, p_bits=P_UNIFORM, tile=64,
                    algorithm="gpfq", constrain=True)
    qm = calibrate_and_quantize(params, cfg, calib, ptq)
    report = collect_observations(qm)
    plan = search_plan(report)
    qm2 = apply_plan(qm, plan)
    return cfg, params, calib, evalb, ptq, qm, report, plan, qm2


def _paged(cfg, params, **kw):
    pc = dict(block_size=8, num_blocks=16, max_concurrency=3,
              max_pages_per_seq=4, chunk_max=4, attn_impl="ref")
    engine_kw = {k: kw.pop(k) for k in ("observe", "kv_scales") if k in kw}
    pc.update(kw)
    return PagedEngine(params, cfg, PagedConfig(**pc), GREEDY, **engine_kw)


# ---------------------------------------------------------------------------
# Observer records
# ---------------------------------------------------------------------------
def test_min_feasible_p_bits_certificate_exact(calibrated):
    """The floor is exact: the codes certify at p* and fail at p* - 1."""
    *_, qm, report, _, _ = calibrated
    checked = 0
    for _, ql in qm.quantized_linears():
        if ql.cert is None:
            continue
        k = int(ql.q_int.shape[-2])
        p_star = min_feasible_p_bits(ql.cert, k)
        assert p_star <= ql.spec.p_inner
        assert bool(certify(ql.q_int, ql.cfg.act_alphabet, p_star, ql.spec.tile))
        assert not bool(
            certify(ql.q_int, ql.cfg.act_alphabet, p_star - 1, ql.spec.tile))
        checked += 1
        if checked >= 3:  # exactness is per-site; three sites suffice
            break
    assert checked == 3


def test_report_joins_cert_and_activation_observer(calibrated):
    cfg, *_, report, _, _ = calibrated
    assert len(report.sites) == 7  # one slot, 7 sites (wq wk wv wo wg wu wd)
    for s in report:
        assert s.n_repeats == cfg.n_layers  # tiny-lm-xs: period 1
        assert s.headroom_bits is not None and s.headroom_bits > 0
        assert s.p_floor <= s.p_inner == P_UNIFORM
        # merged ActObserver snapshot over repeats
        assert s.act["n_batches"] > 0
        assert s.act["lo"] <= s.act["hi"]
        assert s.act["min_seen"] <= s.act["max_seen"]
        assert s.act["absmax"] >= 0
    assert report.accumulator_bits() == 7 * cfg.n_layers * P_UNIFORM
    assert report.floor_accumulator_bits() < report.accumulator_bits()
    assert report.binding_site() in report.sites


def test_cert_summary_names_binding_site(calibrated):
    *_, qm, _, _, _ = calibrated
    s = qm.cert_summary()
    assert s["ok"]
    by_name = {n: ql.cert.headroom_bits for n, ql in qm.quantized_linears()
               if ql.cert is not None}
    assert s["min_headroom_site"] in by_name
    assert by_name[s["min_headroom_site"]] == s["min_headroom_bits"]
    assert s["min_headroom_bits"] == min(by_name.values())


def test_site_key_for_path():
    assert site_key_for_path("params/layers[2]/mixer/wq") == "slot2/mixer.wq"
    assert site_key_for_path("p/layers[0]/ffn/moe/wd") == "slot0/ffn.moe.wd"
    assert site_key_for_path("embedding/table") is None


# ---------------------------------------------------------------------------
# Search: tighter budget, bit-identical proxy loss
# ---------------------------------------------------------------------------
def test_search_tightens_budget_bit_identical(calibrated):
    """The acceptance property: the searched plan meets a strictly tighter
    global accumulator budget at *bit-identical* perplexity (P_I-only
    re-spec serves the same codes), with every certificate re-issued."""
    *_, evalb, _, qm, report, plan, qm2 = calibrated
    searched = plan_accumulator_bits(plan, report)
    assert searched < report.accumulator_bits()
    assert searched == plan.meta["searched_bits"]
    assert qm2.cert_summary()["ok"]
    for name, spec in plan.items():
        assert spec.p_inner >= report.sites[name].p_floor
    ppl_u = quantized_ppl(qm, evalb)
    ppl_s = quantized_ppl(qm2, evalb)
    assert ppl_s == ppl_u  # exact: same codes, same scales, same quantizers


def test_search_respects_explicit_budget(calibrated):
    *_, report, plan, _ = calibrated
    floor, uniform = plan.meta["floor_bits"], plan.meta["uniform_bits"]
    assert floor < uniform
    mid = floor + (uniform - floor) // 2
    plan_mid = search_plan(report, acc_budget_bits=mid)
    assert plan_accumulator_bits(plan_mid, report) <= mid
    with pytest.raises(ValueError, match="below the certificate-exact floor"):
        search_plan(report, acc_budget_bits=floor - 1)


def test_search_margin_lifts_floors(calibrated):
    *_, report, _, _ = calibrated
    plan_m = search_plan(report, margin_bits=2)
    for s in report:
        p = plan_m.get(s.name)
        got = p.p_inner if p is not None else s.p_inner
        assert got >= min(s.p_floor + 2, s.p_inner)


def test_search_order_deterministic_on_equal_headroom():
    """Regression: sites with identical headroom (e.g. all-zero sites, which
    certify at a shared finite headroom) used to be ordered by dict/sort
    instability. Every selection now tie-breaks on the site name, so the
    same report — in any insertion order — yields the same plan."""
    from repro.quant.observe.records import ObserverReport, SiteObservation

    def site(name):
        return SiteObservation(
            name=name, k=64, n_repeats=1,
            spec=DatapathSpec(tile=16, p_inner=16, p_outer=18),
            headroom_bits=3.0, p_floor=13, n_weights=64 * 8, act={},
        )

    names = [f"slot0/mixer.w{c}" for c in "qkvo"]
    fwd = ObserverReport(sites={n: site(n) for n in names})
    rev = ObserverReport(sites={n: site(n) for n in reversed(names)})

    for kwargs in (
        {"promote_w8": 2},
        {"sparsify": 2},
        {"acc_budget_bits": 4 * 13 + 2},  # 2 bits of slack to hand out
    ):
        p1 = search_plan(fwd, **kwargs)
        p2 = search_plan(rev, **kwargs)
        assert {k: v.key() for k, v in p1.sites.items()} == \
               {k: v.key() for k, v in p2.sites.items()}, kwargs
        assert p1.meta.get("promoted_w8") == p2.meta.get("promoted_w8")
        assert p1.meta.get("sparsified") == p2.meta.get("sparsified")
    # pinned selections: name order breaks the tie
    expect = ["slot0/mixer.wk", "slot0/mixer.wo"]
    assert search_plan(fwd, promote_w8=2).meta["promoted_w8"] == expect
    assert search_plan(fwd, sparsify=2).meta["sparsified"] == expect


def test_sparsify_move_marks_most_headroomed_eligible_sites():
    """The sparsify move targets eligible sites (K % 4 == 0, w<=4, dense)
    by descending headroom, excludes them from P_I tightening, and stamps
    a code-changing 2:4 spec that apply_plan refuses."""
    from repro.quant.observe.records import ObserverReport, SiteObservation

    def site(name, headroom, k=64, w_bits=4, sparsity=None):
        spec = dataclasses.replace(
            DatapathSpec(tile=16, p_inner=16, p_outer=18),
            w_bits=w_bits, sparsity=sparsity,
        )
        return SiteObservation(
            name=name, k=k, n_repeats=1, spec=spec,
            headroom_bits=headroom, p_floor=13, n_weights=k * 8, act={},
        )

    report = ObserverReport(sites={s.name: s for s in [
        site("slot0/mixer.wq", 5.0),
        site("slot0/mixer.wk", 3.0),
        site("slot0/mixer.wv", 4.0, k=66),          # K % 4 != 0: ineligible
        site("slot0/ffn.wu", 6.0, sparsity="2:4"),  # already sparse
        site("slot0/ffn.wd", 7.0, w_bits=8),        # no int4 container
    ]})
    plan = search_plan(report, sparsify=2)
    assert plan.meta["sparsified"] == ["slot0/mixer.wk", "slot0/mixer.wq"]
    for n in plan.meta["sparsified"]:
        assert plan[n].sparsity == "2:4"
        assert plan[n].p_inner == 16  # registers untouched: floors move
        # only after the mask-aware re-calibration


def test_plan_json_roundtrip(tmp_path, calibrated):
    *_, plan, _ = calibrated
    path = str(tmp_path / "plan.json")
    plan.save(path)
    back = MixedPrecisionPlan.load(path)
    assert set(back.keys()) == set(plan.keys())
    for k in plan:
        assert back[k] == plan[k]
    assert back.meta["acc_budget_bits"] == plan.meta["acc_budget_bits"]


def test_apply_plan_rejects_unknown_site(calibrated):
    *_, qm, _, plan, _ = calibrated
    bogus = MixedPrecisionPlan(
        sites={"slot9/mixer.nope": next(iter(plan.items()))[1]})
    with pytest.raises(DatapathMismatchError, match="unknown sites"):
        apply_plan(qm, bogus)


def test_apply_plan_rejects_code_alphabet_moves(calibrated):
    """w/act/tile changes alter the codes: re-spec must refuse and point at
    calibrate_and_quantize(plan=...)."""
    *_, qm, _, plan, _ = calibrated
    name, spec = next(iter(plan.items()))
    w8 = dataclasses.replace(spec, w_bits=8)
    with pytest.raises(DatapathMismatchError, match="code alphabet"):
        apply_plan(qm, MixedPrecisionPlan(sites={name: w8}))


def test_promote_w8_drives_recalibration(calibrated):
    """w_bits moves go through the pipeline: the promoted (most binding)
    site leaves the integer accumulator budget, so it loses its
    certificate while every other site stays certified."""
    cfg, params, calib, _, ptq, qm, report, _, _ = calibrated
    plan_p = search_plan(report, promote_w8=1)
    [promoted] = plan_p.meta["promoted_w8"]
    assert promoted == report.binding_site()
    assert plan_p[promoted].w_bits == 8
    assert plan_p[promoted].p_inner == 32
    with pytest.raises(DatapathMismatchError, match="code alphabet"):
        apply_plan(qm, plan_p)

    qm3 = calibrate_and_quantize(params, cfg, calib, ptq, plan=plan_p)
    s = qm3.cert_summary()
    n_sites = len(report.sites)
    assert s["n_certified"] == (n_sites - 1) * cfg.n_layers
    assert s["ok"]


def test_pipeline_rejects_unknown_plan_site(calibrated):
    cfg, params, calib, _, ptq, _, _, plan, _ = calibrated
    bogus = MixedPrecisionPlan(
        sites={"slot0/mixer.nope": next(iter(plan.items()))[1]})
    with pytest.raises(DatapathMismatchError, match="unknown sites"):
        calibrate_and_quantize(params, cfg, calib, ptq, plan=bogus)


# ---------------------------------------------------------------------------
# Mixed-precision artifacts: export, strict reload, per-site validation
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def mixed_artifact(tmp_path_factory, calibrated):
    """A guaranteed-heterogeneous artifact: the searched plan with one
    site bumped a bit above its floor, so at least two distinct per-site
    datapaths coexist (the search itself may legitimately land uniform
    when every site floors at the same register)."""
    from repro.core import outer_accumulator_bits

    cfg, params, _, _, _, qm, report, plan, _ = calibrated
    sites = dict(plan.sites)
    name = sorted(sites)[0]
    spec = sites[name]
    p_new = spec.p_inner + 1
    k = report.sites[name].k
    p_out = (p_new if spec.tile is None or spec.tile >= k
             else outer_accumulator_bits(p_new, k, spec.tile))
    sites[name] = dataclasses.replace(spec, p_inner=p_new, p_outer=p_out)
    plan_h = MixedPrecisionPlan(sites=sites, meta=dict(plan.meta))
    qm2h = apply_plan(qm, plan_h)

    out = str(tmp_path_factory.mktemp("mixed") / "quantized")
    artifact, meta = export_quantized_artifact(qm2h)
    save_pytree(artifact, out, meta)
    return out, plan_h, qm2h, meta


def test_mixed_artifact_flags_and_strict_load(mixed_artifact, calibrated):
    cfg, params, *_ = calibrated
    out, _, _, meta = mixed_artifact
    assert meta["mixed_precision"] is True  # heterogeneous P_I across sites
    flat, meta2 = load_flat_artifact(out)
    assert meta2["mixed_precision"] is True
    pp = packed_params_from_artifact(flat, params, cfg, meta=meta2)
    n_packed = sum(1 for leaf in jax.tree.leaves(
        pp["layers"], is_leaf=lambda x: isinstance(x, dict) and "packed" in x)
        if isinstance(leaf, dict))
    assert n_packed > 0


def test_mixed_artifact_serves_bit_identical(mixed_artifact, calibrated):
    """Disk -> engine greedy identity vs the in-memory plan (the artifact
    carries everything; nothing is re-derived at load)."""
    cfg, params, *_ = calibrated
    out, plan_h, qm2h, _ = mixed_artifact
    flat, meta = load_flat_artifact(out)
    sp_mem = serving_params_from_quantized(qm2h)
    sp_disk = packed_params_from_artifact(flat, params, cfg, meta=meta)

    base = dataclasses.replace(
        qm2h.ptq.to_datapath_spec(cfg.d_model), static_act=True)
    expected = plan_expected_specs(cfg, plan_h, base)
    assert validate_datapath(sp_mem, expected) == len(expected)
    assert validate_datapath(sp_disk, expected) == len(expected)

    prompts = np.random.default_rng(2).integers(
        0, cfg.vocab, size=(2, 8)).astype(np.int32)
    out_mem = _paged(cfg, sp_mem).generate(prompts, 8)
    out_disk = _paged(cfg, sp_disk).generate(prompts, 8)
    np.testing.assert_array_equal(out_mem, out_disk)


def test_partial_mixed_artifact_rejected(mixed_artifact, calibrated):
    """Satellite: a dropped site must raise loudly, not silently serve
    float. Mixed artifacts force strict accounting from their meta."""
    cfg, params, *_ = calibrated
    out, *_ = mixed_artifact
    flat, meta = load_flat_artifact(out)
    assert meta["mixed_precision"] is True

    # one missing repeat: rejected regardless of strictness
    partial = {k: v for k, v in flat.items()
               if not k.startswith("layer0/mixer.wq/")}
    with pytest.raises(DatapathMismatchError, match="does not cover"):
        packed_params_from_artifact(partial, params, cfg, meta=meta,
                                    strict=False)

    # a whole site dropped: strict (auto-on for mixed_precision) rejects
    dropped = {k: v for k, v in flat.items() if "/mixer.wq/" not in k}
    with pytest.raises(DatapathMismatchError, match="does not cover"):
        packed_params_from_artifact(dropped, params, cfg, meta=meta)


def test_unknown_artifact_site_rejected(mixed_artifact, calibrated):
    cfg, params, *_ = calibrated
    out, *_ = mixed_artifact
    flat, meta = load_flat_artifact(out)
    flat = dict(flat)
    flat["layer0/mixer.bogus/q"] = np.zeros((4, 4), np.int8)
    with pytest.raises(DatapathMismatchError, match="does not enumerate"):
        packed_params_from_artifact(flat, params, cfg, meta=meta)


def test_plan_expected_specs_rejects_unknown_site(calibrated):
    cfg, *_, plan, qm2 = calibrated
    base = dataclasses.replace(
        qm2.ptq.to_datapath_spec(cfg.d_model), static_act=True)
    bogus = MixedPrecisionPlan(sites={"slot0/ffn.nope": base})
    with pytest.raises(DatapathMismatchError, match="does not enumerate"):
        plan_expected_specs(cfg, bogus, base)


def test_validate_datapath_mapping_is_total(calibrated):
    """Per-site validation is bidirectionally total: an unmapped packed
    leaf raises, and a mapped-but-absent site raises (it would silently
    serve float)."""
    cfg, *_, plan, qm2 = calibrated
    sp = serving_params_from_quantized(qm2)
    base = dataclasses.replace(
        qm2.ptq.to_datapath_spec(cfg.d_model), static_act=True)
    expected = plan_expected_specs(cfg, plan, base)

    short = dict(expected)
    short.pop("slot0/mixer.wq")
    with pytest.raises(DatapathMismatchError, match="not named by"):
        validate_datapath(sp, short)

    extra = dict(expected)
    extra["slot0/mixer.ghost"] = base
    with pytest.raises(DatapathMismatchError, match="no packed leaf"):
        validate_datapath(sp, extra)

    wrong = dict(expected)
    wrong["slot0/mixer.wq"] = dataclasses.replace(
        expected["slot0/mixer.wq"], p_inner=12)
    with pytest.raises(DatapathMismatchError):
        validate_datapath(sp, wrong)


def test_two_site_overrides_roundtrip_hybrid(tmp_path):
    """Satellite e2e on a second family: two sites with *different*
    per-site datapaths quantize, certify, export, reload, and serve
    bit-identically through the paged engine."""
    cfg = get_config("tiny-hybrid")
    params = init_model(jax.random.key(0), cfg)
    data = TokenBatcher(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2))
    ptq = PTQConfig(w_bits=4, act_bits=8, p_bits=P_UNIFORM, tile=None,
                    algorithm="gpfq", constrain=True)
    qm = calibrate_and_quantize(params, cfg, [data.batch(0)], ptq)
    report = collect_observations(qm)

    # hand-build a two-site plan with distinct registers (floor vs floor+1)
    certed = [s for s in report if s.headroom_bits is not None][:2]
    assert len(certed) == 2
    a, b = certed
    plan = MixedPrecisionPlan(sites={
        a.name: dataclasses.replace(a.spec, p_inner=a.p_floor,
                                    p_outer=a.p_floor),
        b.name: dataclasses.replace(
            b.spec, p_inner=min(b.p_floor + 1, b.p_inner),
            p_outer=min(b.p_floor + 1, b.p_inner)),
    })
    assert plan[a.name].p_inner != plan[b.name].p_inner or a.p_floor != b.p_floor
    qm2 = apply_plan(qm, plan)
    assert qm2.cert_summary()["ok"]

    artifact, meta = export_quantized_artifact(qm2)
    assert meta["mixed_precision"] is True
    out = str(tmp_path / "hybrid")
    save_pytree(artifact, out, meta)
    flat, meta2 = load_flat_artifact(out)
    sp_mem = serving_params_from_quantized(qm2)
    sp_disk = packed_params_from_artifact(flat, params, cfg, meta=meta2)

    prompts = np.random.default_rng(3).integers(
        0, cfg.vocab, size=(2, 8)).astype(np.int32)
    out_mem = _paged(cfg, sp_mem).generate(prompts, 8)
    out_disk = _paged(cfg, sp_disk).generate(prompts, 8)
    np.testing.assert_array_equal(out_mem, out_disk)


# ---------------------------------------------------------------------------
# Serving observation: structural transparency + saturation counters
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def packed_setup():
    cfg = get_smoke("smollm-360m").scaled(n_layers=2, vocab=128)
    params = init_model(jax.random.key(0), cfg)
    pparams = pack_decode_params(params, cfg)
    prompts = np.random.default_rng(0).integers(
        0, 128, size=(3, 8)).astype(np.int32)
    return cfg, params, pparams, prompts


def test_observer_structurally_transparent(packed_setup):
    """Acceptance assertion: with observation disabled the decode-chunk
    jaxpr is *unchanged* — the counters live entirely off the hot path."""
    cfg, _, pparams, _ = packed_setup
    plain = _paged(cfg, pparams, kv_dtype="int8")
    observed = _paged(cfg, pparams, kv_dtype="int8", observe=True)
    assert observed.datapath_fingerprint.endswith("+obs")
    # bare traces (no observer attached) are structurally identical
    assert str(plain.decode_chunk_jaxpr()) == str(observed.decode_chunk_jaxpr())
    assert "debug_callback" not in str(plain.decode_chunk_jaxpr())
    # with an observer attached, the host taps appear
    tapped = str(observed.decode_chunk_jaxpr(observer=SaturationCounters()))
    assert "debug_callback" in tapped


def test_observed_serving_bit_identical_with_report(packed_setup):
    cfg, _, pparams, prompts = packed_setup
    plain = _paged(cfg, pparams, kv_dtype="int8")
    observed = _paged(cfg, pparams, kv_dtype="int8", observe=True)
    ref = plain.generate(prompts, 8)
    out = observed.generate(prompts, 8)
    np.testing.assert_array_equal(out, ref)  # counters never touch values

    observed.assert_observation_transparent()
    rep = observed.saturation_report()
    assert rep["sites"], "packed sites must have recorded"
    for name, site in rep["sites"].items():
        assert name.startswith("slot")
        assert site["n_calls"] > 0 and site["clip_total"] > 0
        assert 0.0 <= site["clip_frac"] <= 1.0
        # packed-leaf watermark section resolved for every observed site
        assert site["watermark_bits"] > 0
        # headroom is measured against the exact register limit (2^(p-1)-1)
        assert site["headroom_bits_observed"] == pytest.approx(
            site["p_inner"] - site["watermark_bits"], abs=1e-3)
    # int8 KV pools: per-head accumulator watermarks vs the attn registers
    assert rep["kv_heads"]
    for slot in rep["kv_heads"].values():
        assert slot  # every int8 attn slot reports each kv head
        for head in slot.values():
            assert np.isfinite(head["qk_watermark_bits"])
            assert np.isfinite(head["pv_watermark_bits"])
            assert 0 < head["qk_watermark_bits"] <= head["p_qk"]
            assert 0 < head["pv_watermark_bits"] <= head["p_pv"]

    with pytest.raises(ValueError, match="observe"):
        plain.saturation_report()


def test_static_kv_scales_roundtrip_identity(packed_setup, tmp_path):
    """Calibrated static page scales: plan kv section drives the engine,
    JSON round-trip preserves greedy outputs bit-exactly, and the engine
    refuses scales on float pools."""
    cfg, _, pparams, prompts = packed_setup
    batch = {"tokens": jnp.asarray(prompts)}
    ranges = observe_kv_ranges(pparams, cfg, [batch])
    kv = search_kv_bits(ranges, kv_bits=8, low_bits=4, low_frac=0.25)

    eng = _paged(cfg, pparams, kv_dtype="int8", kv_scales=kv)
    assert eng.datapath_fingerprint.endswith("+kv-static")
    out_a = eng.generate(prompts, 8)

    plan = MixedPrecisionPlan(sites={}, kv=kv)
    path = str(tmp_path / "plan.json")
    plan.save(path)
    kv_back = MixedPrecisionPlan.load(path).kv
    out_b = _paged(cfg, pparams, kv_dtype="int8", kv_scales=kv_back).generate(
        prompts, 8)
    np.testing.assert_array_equal(out_a, out_b)

    with pytest.raises(ValueError, match="int8"):
        _paged(cfg, pparams, kv_scales=kv)
    with pytest.raises(ValueError, match="not an attention slot"):
        bad = {"slots": {"99": kv["slots"][next(iter(kv["slots"]))]},
               "kv_bits_default": 8}
        _paged(cfg, pparams, kv_dtype="int8", kv_scales=bad)


# ---------------------------------------------------------------------------
# Launcher: search -> export -> validated serve surface
# ---------------------------------------------------------------------------
def test_search_launcher_end_to_end(tmp_path):
    from repro.launch.search import main

    out = str(tmp_path / "mixed")
    rep = main([
        "--arch", "tiny-lm-xs", "--p-bits", str(P_UNIFORM), "--tile", "64",
        "--calib-batches", "1", "--calib-batch-size", "2", "--seq", "32",
        "--eval-batches", "1", "--kv-static", "--out", out,
    ])
    assert rep["savings_rate"] > 1.0
    assert rep["searched"]["ppl"] == rep["uniform"]["ppl"]  # P_I-only plan
    assert rep["searched"]["cert"]["ok"]
    assert rep["searched"]["kv_static"]

    plan = MixedPrecisionPlan.load(f"{out}/plan.json")
    assert plan.kv is not None and plan.meta["base_spec"]["p_inner"] == P_UNIFORM
    cfg = get_config("tiny-lm-xs")
    params = init_model(jax.random.key(0), cfg)
    flat, meta = load_flat_artifact(f"{out}/quantized")
    pp = packed_params_from_artifact(flat, params, cfg, meta=meta)
    base = DatapathSpec(**plan.meta["base_spec"])
    n = validate_datapath(pp, plan_expected_specs(cfg, plan, base))
    assert n == 7
