"""Shared test config.

NOTE: do NOT set XLA_FLAGS / host device count here — smoke tests and
benches must see the real single-device CPU. Multi-device dry-run tests
spawn subprocesses with their own XLA_FLAGS (see test_dryrun.py).

``hypothesis`` is optional: when absent, the settings profile is skipped
and property-based tests importing it are collected as skips via their
own module-level ``pytest.importorskip`` guards.
"""

import numpy as np
import pytest

try:
    from hypothesis import settings
except ImportError:  # pragma: no cover - exercised on minimal CI boxes
    settings = None

if settings is not None:
    # Single-core CI box: keep hypothesis snappy and deadline-free (JAX jit
    # compilation on first example would otherwise trip per-example deadlines).
    settings.register_profile("ci", max_examples=25, deadline=None, derandomize=True)
    settings.load_profile("ci")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
