"""Shared test config.

NOTE: do NOT set XLA_FLAGS / host device count here — smoke tests and
benches must see the real single-device CPU. Multi-device dry-run tests
spawn subprocesses with their own XLA_FLAGS (see test_dryrun.py).
"""

import numpy as np
import pytest
from hypothesis import settings

# Single-core CI box: keep hypothesis snappy and deadline-free (JAX jit
# compilation on first example would otherwise trip per-example deadlines).
settings.register_profile("ci", max_examples=25, deadline=None, derandomize=True)
settings.load_profile("ci")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
