"""Dry-run machinery test: spawn the real dryrun CLI in a subprocess with a
small fake-device mesh (the production 512-device runs are executed by the
EXPERIMENTS harness; this guards the machinery itself). Subprocess isolation
is required because XLA locks the host device count at first init."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(args, devices=8, timeout=900):
    env = dict(
        os.environ,
        PYTHONPATH=os.path.join(REPO, "src"),
        REPRO_DRYRUN_XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
    )
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, env=env, timeout=timeout, cwd=REPO,
    )


@pytest.mark.slow
def test_dryrun_train_cell(tmp_path):
    out = str(tmp_path)
    r = _run_dryrun(
        ["--arch", "smollm-360m", "--shape", "train_4k", "--mesh-shape", "2,4",
         "--out", out]
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    fname = os.path.join(out, "smollm-360m__train_4k__2,4.json")
    rec = json.load(open(fname))
    assert rec["status"] == "ok"
    rl = rec["roofline"]
    assert rl["flops_per_dev"] > 0
    assert rl["coll_bytes_per_dev"] > 0  # FSDP/TP must produce collectives
    assert rl["dominant"] in ("compute", "memory", "collective")
    assert rec["hlo_stats"]["max_trip_product"] > 1  # scans were corrected


def test_paged_budget_cli(tmp_path):
    """--paged-budget is pure sharding arithmetic (no compile), so it is
    fast even over the production serving archs; the per-device numbers
    must come from the resolved specs, and every arch must fit."""
    out = str(tmp_path)
    r = _run_dryrun(["--paged-budget", "--mesh", "single", "--out", out],
                    devices=256, timeout=300)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.load(open(os.path.join(
        out, "llama3-405b__paged_budget__single.json")))
    assert rec["fits"] and rec["max_pool_blocks"] >= 1
    assert rec["chips"] == 256
    assert 0 < rec["weight_bytes_per_dev"] < rec["hbm_per_chip_bytes"]
    assert rec["kv_page_bytes_per_dev"] > 0
    assert rec["interconnect"]["decode_ici_floor_us_per_tok"] > 0
    # int8 pages halve the per-page bytes -> more blocks in the budget
    r8 = _run_dryrun(["--paged-budget", "--arch", "llama3-405b",
                      "--kv-dtype", "int8", "--mesh", "single",
                      "--out", out], devices=256, timeout=300)
    assert r8.returncode == 0, r8.stdout[-2000:] + r8.stderr[-2000:]
    rec8 = json.load(open(os.path.join(
        out, "llama3-405b__paged_budget__single.json")))
    assert rec8["max_pool_blocks"] > rec["max_pool_blocks"]
    # an 8-chip mesh cannot hold 405B weights: the budget must say OOM
    # (exit 1), not fabricate a fitting pool
    r_oom = _run_dryrun(["--paged-budget", "--arch", "llama3-405b",
                         "--mesh-shape", "2,4"], timeout=300)
    assert r_oom.returncode == 1
    assert "OOM" in r_oom.stdout


@pytest.mark.slow
def test_dryrun_multipod_axis(tmp_path):
    """3D mesh (pod axis) lowers and compiles."""
    out = str(tmp_path)
    r = _run_dryrun(
        ["--arch", "smollm-360m", "--shape", "decode_32k",
         "--mesh-shape", "2,2,2", "--out", out]
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.load(open(os.path.join(out, "smollm-360m__decode_32k__2,2,2.json")))
    assert rec["status"] == "ok"
    assert rec["chips"] == 8
