"""Device-resident decode datapath: fused W4A8 kernel dispatch through the
model forwards (dense / MoE / Mamba / xLSTM / hybrid), jaxpr hygiene (the
kernel path must never materialize the full bf16 weight), and the packed
artifact's pack-time ``col_sums`` term."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke
from repro.models import transformer as T
from repro.models.layers import (
    packed_linear,
    use_packed_backend,
)
from repro.quant.serve_packed import _pack_leaf, pack_decode_params

FAMILY_ARCHS = ["tiny-moe", "tiny-ssm", "tiny-xlstm", "tiny-hybrid"]


def _corr(a, b) -> float:
    return float(jnp.corrcoef(jnp.ravel(a), jnp.ravel(b))[0, 1])


# ---------------------------------------------------------------------------
# Site-level dispatch
# ---------------------------------------------------------------------------
def test_packed_linear_kernel_matches_dequant(rng):
    w = jnp.asarray(rng.normal(size=(64, 48)), jnp.float32)
    leaf = _pack_leaf(w)
    x = jnp.asarray(rng.normal(size=(3, 5, 64)), jnp.float32)
    with use_packed_backend("dequant"):
        yd = packed_linear(x, leaf)
    with use_packed_backend("interpret"):
        yk = packed_linear(x, leaf)
    assert yk.shape == yd.shape == (3, 5, 48)
    # only difference is the dynamic int8 activation quantization
    assert _corr(yd, yk) > 0.999


def test_packed_artifact_col_sums_matches_codes(rng):
    from repro.kernels.w4a8_mm import unpack_int4

    w = jnp.asarray(rng.normal(size=(32, 24)), jnp.float32)
    leaf = _pack_leaf(w)
    assert leaf["col_sums"].dtype == jnp.int32
    assert leaf["col_sums"].shape == (1, 24)
    expect = jnp.sum(unpack_int4(leaf["packed"]).astype(jnp.int32), axis=-2)
    np.testing.assert_array_equal(
        np.asarray(leaf["col_sums"][0]), np.asarray(expect)
    )


def test_packed_linear_legacy_artifact_without_col_sums(rng):
    """Artifacts packed before this PR (no col_sums leaf) still dispatch."""
    w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    leaf = {k: v for k, v in _pack_leaf(w).items() if k != "col_sums"}
    x = jnp.asarray(rng.normal(size=(2, 64)), jnp.float32)
    with use_packed_backend("interpret"):
        yk = packed_linear(x, leaf)
    with use_packed_backend("dequant"):
        yd = packed_linear(x, leaf)
    assert _corr(yd, yk) > 0.999


def test_ensure_col_sums_fills_legacy_leaves(rng):
    """One-time load-path fix for legacy artifacts: missing col_sums leaves
    are filled (exactly), complete leaves and float leaves are untouched."""
    from repro.quant.serve_packed import ensure_col_sums

    full = _pack_leaf(jnp.asarray(rng.normal(size=(32, 16)), jnp.float32))
    legacy = {k: v for k, v in full.items() if k != "col_sums"}
    tree = {
        "layers": ({"mixer": {"wq": legacy, "wo": jnp.ones((4, 4))}},),
        "embedding": {"embed": jnp.ones((8, 4))},
    }
    fixed = ensure_col_sums(tree)
    got = fixed["layers"][0]["mixer"]["wq"]["col_sums"]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(full["col_sums"]))
    assert fixed["layers"][0]["mixer"]["wo"] is tree["layers"][0]["mixer"]["wo"]
    assert fixed["embedding"]["embed"] is tree["embedding"]["embed"]


def test_engine_backend_switch_retraces():
    """The resolved packed backend is part of the engine's jit cache key:
    switching backends between calls retraces instead of silently reusing
    the previously compiled datapath."""
    from repro.serving import GenerationEngine, SamplerConfig

    cfg = get_smoke("smollm-360m").scaled(n_layers=1, vocab=64)
    params = T.init_model(jax.random.key(0), cfg)
    pparams = pack_decode_params(params, cfg)
    prompts = np.random.default_rng(0).integers(0, 64, size=(2, 4)).astype(np.int32)
    eng = GenerationEngine(pparams, cfg, SamplerConfig(temperature=0.0))
    with use_packed_backend("dequant"):
        eng.generate(prompts, 2)
        eng.generate(prompts, 2)
    assert eng.gen_traces == 1
    with use_packed_backend("interpret"):
        eng.generate(prompts, 2)  # same shapes, new backend -> new trace
    assert eng.gen_traces == 2
    with use_packed_backend("dequant"):
        eng.generate(prompts, 2)  # first backend's compile is still cached
    assert eng.gen_traces == 2


# ---------------------------------------------------------------------------
# Jaxpr hygiene: the kernel path must not dequantize the full weight
# ---------------------------------------------------------------------------
def _all_eqns(jaxpr, out):
    for eqn in jaxpr.eqns:
        out.append(eqn)
        for v in eqn.params.values():
            vals = v if isinstance(v, (list, tuple)) else [v]
            for x in vals:
                inner = getattr(x, "jaxpr", x)
                if hasattr(inner, "eqns"):
                    _all_eqns(inner, out)
    return out


def test_kernel_path_jaxpr_has_no_full_weight_dequant(rng):
    """On the kernel path the packed codes are only ever touched inside the
    pallas call, block by block: no (K, N)-shaped tensor — float dequant or
    int unpack — may appear anywhere in the jaxpr. (The dequant fallback
    does produce one; that asserts the detector actually detects.)"""
    K, N = 256, 256
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    leaf = _pack_leaf(w)
    x = jnp.asarray(rng.normal(size=(4, K)), jnp.float32)

    def full_weight_eqns(backend):
        with use_packed_backend(backend):
            # fresh lambda: make_jaxpr caches traces per function object,
            # which would hide the backend switch
            jaxpr = jax.make_jaxpr(lambda a, l: packed_linear(a, l))(x, leaf).jaxpr
        eqns = _all_eqns(jaxpr, [])
        hits = [
            e for e in eqns
            for ov in e.outvars
            if getattr(ov.aval, "shape", None) == (K, N)
        ]
        has_pallas = any("pallas" in e.primitive.name for e in eqns)
        return hits, has_pallas

    hits, has_pallas = full_weight_eqns("interpret")
    assert has_pallas, "kernel path must lower to a pallas_call"
    assert not hits, f"full-weight tensors on the kernel path: {hits}"

    hits_dq, _ = full_weight_eqns("dequant")
    assert hits_dq, "detector sanity: dequant fallback materializes (K, N)"


# ---------------------------------------------------------------------------
# Family coverage: packed decode rides the integer datapath everywhere
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_family_decode_step_kernel_vs_dequant(arch):
    """decode_step with packed params: fused-kernel (interpret) logits track
    the in-graph dequant fallback on every family tiny config."""
    cfg = get_config(arch)
    params = T.init_model(jax.random.key(0), cfg)
    pparams = pack_decode_params(params, cfg)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab)}
    tok = jnp.ones((2, 1), jnp.int32)
    outs = {}
    for backend in ("dequant", "interpret"):
        with use_packed_backend(backend):
            _, cache = T.prefill(pparams, batch, cfg, max_len=12)
            logits, _ = T.decode_step(pparams, tok, cache, jnp.int32(8), cfg)
            outs[backend] = logits
    c = _corr(outs["dequant"], outs["interpret"])
    assert c > 0.99, (arch, c)
    assert bool(jnp.all(jnp.isfinite(outs["interpret"])))


def test_dense_prefill_kernel_vs_dequant():
    """The prefill-shaped path (M = B*S, ragged) through the same dispatch."""
    cfg = get_smoke("smollm-360m").scaled(n_layers=2, vocab=128)
    params = T.init_model(jax.random.key(0), cfg)
    pparams = pack_decode_params(params, cfg)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (3, 7), 0, 128)}
    with use_packed_backend("dequant"):
        ld, _ = T.forward(pparams, batch, cfg)
    with use_packed_backend("interpret"):
        lk, _ = T.forward(pparams, batch, cfg)
    assert _corr(ld, lk) > 0.99


def test_fused_generate_on_kernel_backend():
    """End to end: the on-device generation loop with every packed matmul
    dispatched to the (interpret-mode) W4A8 kernel."""
    from repro.serving import GenerationEngine, SamplerConfig

    cfg = get_smoke("smollm-360m").scaled(n_layers=1, vocab=64)
    params = T.init_model(jax.random.key(0), cfg)
    pparams = pack_decode_params(params, cfg)
    prompts = np.random.default_rng(0).integers(0, 64, size=(2, 4)).astype(np.int32)
    eng = GenerationEngine(pparams, cfg, SamplerConfig(temperature=0.0))
    with use_packed_backend("interpret"):
        out_k = eng.generate(prompts, 3)
    assert out_k.shape == (2, 7)
    eng_d = GenerationEngine(pparams, cfg, SamplerConfig(temperature=0.0))
    with use_packed_backend("dequant"):
        out_d = eng_d.generate(prompts, 3)
    # greedy argmax over near-identical logits: tokens rarely diverge on a
    # 3-token horizon; require exact prompt echo + valid token range
    np.testing.assert_array_equal(out_k[:, :4], prompts)
    assert out_d.shape == out_k.shape
