"""Render EXPERIMENTS.md tables from the dry-run artifacts.

    python scripts/make_tables.py results/dryrun        # roofline table md
    python scripts/make_tables.py --perf                # §Perf A/B table md
"""

import glob
import json
import os
import sys

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def roofline_table(d):
    recs = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(f))
        if r.get("status") != "ok":
            recs.append((r, None))
            continue
        recs.append((r, r["roofline"]))
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | dominant"
        " | useful | roofline_frac | GB/dev | compile_s |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    recs.sort(key=lambda t: (t[0]["arch"], SHAPE_ORDER.get(t[0]["shape"], 9),
                             t[0].get("quantized", False), t[0].get("mesh", "")))
    for r, rl in recs:
        tag = r["shape"] + (" +w4a8" if r.get("quantized") else "")
        if rl is None:
            lines.append(f"| {r['arch']} | {tag} | {r['mesh']} | FAIL: "
                         f"{r.get('error','?')[:60]} | | | | | | | |")
            continue
        gb = r["memory"]["peak_bytes_per_device"] / 1e9
        lines.append(
            f"| {r['arch']} | {tag} | {r['mesh']} | {rl['compute_s']:.3e} | "
            f"{rl['memory_s']:.3e} | {rl['collective_s']:.3e} | {rl['dominant']} | "
            f"{rl['useful_flops_ratio']:.3f} | {rl['roofline_fraction']:.5f} | "
            f"{gb:.1f} | {r['compile_s']:.0f} |"
        )
    return "\n".join(lines)


def perf_table():
    cells = [
        ("jamba-1.5-large-398b__prefill_32k__single", "jamba-1.5 prefill_32k"),
        ("granite-moe-3b-a800m__train_4k__single", "granite-moe train_4k"),
        ("dbrx-132b__train_4k__single", "dbrx train_4k (bonus)"),
        ("llama3-405b__decode_32k__single", "llama3-405b decode_32k"),
    ]
    lines = [
        "| cell | variant | compute_s | memory_s | collective_s | dominant | roofline_frac |",
        "|---|---|---|---|---|---|---|",
    ]

    def row(label, variant, r):
        rl = r["roofline"]
        lines.append(
            f"| {label} | {variant} | {rl['compute_s']:.3e} | {rl['memory_s']:.3e} | "
            f"{rl['collective_s']:.3e} | {rl['dominant']} | {rl['roofline_fraction']:.5f} |"
        )

    for fname, label in cells:
        for d, v in (("results/perf_baseline", "baseline"),
                     ("results/perf_opt", "optimized")):
            p = os.path.join(d, fname + ".json")
            if os.path.exists(p):
                row(label, v, json.load(open(p)))
    for p, v in (
        ("results/perf_opt/llama3-405b__decode_32k__w4a8__single.json",
         "w4a8 (+TP-stationary weights)"),
        ("results/perf_opt2/llama3-405b__decode_32k__single.json",
         "hd-sharded KV (refuted)"),
    ):
        if os.path.exists(p):
            row("llama3-405b decode_32k", v, json.load(open(p)))
    return "\n".join(lines)


if __name__ == "__main__":
    if "--perf" in sys.argv:
        print(perf_table())
    else:
        print(roofline_table(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"))
