#!/usr/bin/env bash
# Full local verification: tier-1 tests (slow ones included), the shared
# smoke suite (scripts/smoke.sh — the same script CI runs), the FAST bench
# grid, and the bench regression gate against the committed baselines.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== tier-1: pytest (full, slow included) =="
python -m pytest -x -q

echo "== smoke suite (scripts/smoke.sh) =="
scripts/smoke.sh

echo "== decode + datapath + serving bench smoke (REPRO_BENCH_FAST grid) =="
bench_base=$(mktemp -d)
trap 'rm -rf "${bench_base}"' EXIT
cp BENCH_*.json "${bench_base}/"
REPRO_BENCH_FAST=1 python -m benchmarks.run --only decode,datapath,serving
test -f BENCH_decode.json && echo "BENCH_decode.json written"
test -f BENCH_datapath.json && echo "BENCH_datapath.json written"
test -f BENCH_serving.json && echo "BENCH_serving.json written"

echo "== bench regression gate (scripts/bench_compare.py) =="
# wall-clock on this class of CPU box swings 2-4x run-to-run (frequency
# scaling / noisy neighbors) even with min-of-reps batched timing — the
# local gate is a step-change detector on engine-scale metrics (catches
# the 10x fell-off-the-fused-path class of regression); sub-500us
# single-site timings are floor-skipped. Tighten both on dedicated
# hardware.
REPRO_BENCH_TOLERANCE="${REPRO_BENCH_TOLERANCE:-1.5}" \
REPRO_BENCH_MIN_US="${REPRO_BENCH_MIN_US:-500}" \
  python scripts/bench_compare.py --baseline "${bench_base}" --current .

echo "== all checks passed =="
