#!/usr/bin/env bash
# Tier-1 verification + one tiny end-to-end quantize-and-certify smoke per
# model family (dense, MoE, SSM, xLSTM, hybrid) through the real launcher.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== tier-1: pytest =="
python -m pytest -x -q

for arch in tiny-lm-xs tiny-moe tiny-ssm tiny-xlstm tiny-hybrid; do
  echo "== PTQ smoke: ${arch} =="
  report=$(python -m repro.launch.quantize --arch "${arch}" \
    --calib-batches 1 --calib-batch-size 2 --seq 32 --eval-batches 1)
  echo "${report}" | python -c '
import json, sys
arch = sys.argv[1]
report = json.load(sys.stdin)
cert = report["cert"]
assert cert["ok"], f"{arch}: certification failed: {cert}"
headroom = cert["min_headroom_bits"]
ppl = report["quant_ppl"]
print(f"{arch}: certified ok, min_headroom={headroom:.4f}, quant_ppl={ppl:.2f}")
' "${arch}"
done

echo "== decode bench smoke (REPRO_BENCH_FAST grid) =="
REPRO_BENCH_FAST=1 python -m benchmarks.run --only decode
test -f BENCH_decode.json && echo "BENCH_decode.json written"

echo "== all checks passed =="
