#!/usr/bin/env python3
"""Multi-process CPU-mesh determinism battery for the paged engine.

    python scripts/run_multiprocess.py --procs 2 --devices-per-proc 2

The parent spawns ``--procs`` worker copies of this script, each a real
OS process with its own jax runtime: workers set
``XLA_FLAGS=--xla_force_host_platform_device_count=K`` *before* importing
jax, select the gloo CPU collectives backend, and rendezvous through
``jax.distributed.initialize`` — so the (procs * K)-device global mesh
runs genuine cross-process psum/all-gather collectives, not fake
single-process sharding.

Each worker then runs the battery:

1. serve a mixed trace (mid-flight admission via the ``_late`` hook and
   a watermark preemption forced by a tight pool) through a local
   1-device reference engine AND through the global-mesh engine;
2. assert every token stream byte-equal between the two;
3. assert the final device ``free_list`` / ``page_refcounts`` byte-equal
   to the reference and to the host ``PoolState`` mirror's replay;
4. allgather a blake2b digest of (streams, free state) across processes
   and assert every process computed the identical bytes — the
   multi-controller contract of docs/multihost.md;
5. repeat (1-4) on the int8-KV + prefix-cache engine.

Exit code 0 only when every worker passes. CI runs this as the second
lane of the ``mesh`` job; locally it needs nothing but a free TCP port.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys


def parent(args) -> int:
    env_base = dict(os.environ)
    env_base["JAX_PLATFORMS"] = "cpu"
    procs = []
    for pid in range(args.procs):
        env = dict(env_base)
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices_per_proc}"
        )
        env["REPRO_MP_ROLE"] = "worker"
        env["REPRO_MP_PROC"] = str(pid)
        env["REPRO_MP_NPROCS"] = str(args.procs)
        env["REPRO_MP_COORD"] = f"127.0.0.1:{args.port}"
        procs.append(subprocess.Popen(
            [sys.executable, "-u", os.path.abspath(__file__)],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True,
        ))
    rc = 0
    for pid, p in enumerate(procs):
        out, _ = p.communicate(timeout=args.timeout)
        status = "ok" if p.returncode == 0 else f"FAILED rc={p.returncode}"
        print(f"[run_multiprocess] worker {pid}: {status}")
        if p.returncode != 0 or args.verbose:
            print("\n".join(f"  [{pid}] {line}"
                            for line in out.splitlines()[-40:]))
        rc = rc or p.returncode
    print(f"[run_multiprocess] {'PASS' if rc == 0 else 'FAIL'}: "
          f"{args.procs} processes x {args.devices_per_proc} devices")
    return rc


def worker() -> int:
    # env (XLA_FLAGS included) was staged by the parent before exec — the
    # device count is locked in before jax ever imports
    import jax

    jax.config.update("jax_platforms", "cpu")
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(
        coordinator_address=os.environ["REPRO_MP_COORD"],
        num_processes=int(os.environ["REPRO_MP_NPROCS"]),
        process_id=int(os.environ["REPRO_MP_PROC"]),
    )
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

    import hashlib

    import numpy as np
    from jax.experimental import multihost_utils

    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.models.transformer import init_model
    from repro.runtime import sharding as shardlib
    from repro.serving import (
        PagedConfig,
        PagedEngine,
        Request,
        SamplerConfig,
        SchedulerPolicy,
    )

    pid = jax.process_index()
    n_dev = len(jax.devices())
    print(f"worker {pid}: {n_dev} global devices, "
          f"{len(jax.local_devices())} local")

    cfg = get_config("tiny-lm-xs").scaled(n_layers=2, vocab=128)
    params = init_model(jax.random.key(0), cfg)
    mesh = make_mesh((n_dev // 2, 2) if n_dev % 2 == 0 else (n_dev,))

    rng = np.random.default_rng(11)
    lens = [(8, 8, 0), (8, 6, 1), (16, 8, 0), (8, 12, 1), (24, 4, 0)]
    reqs = [Request(uid=u, prompt=rng.integers(0, 128, size=s).astype(np.int32),
                    max_new=m, priority=p)
            for u, (s, m, p) in enumerate(lens)]
    late = Request(uid=99, prompt=rng.integers(0, 128, size=8).astype(np.int32),
                   max_new=6)

    def battery(tag: str, reqs=reqs, ref_pc=None, min_preempt=0,
                **pc_extra):
        pc = dict(block_size=8, num_blocks=18, max_concurrency=3,
                  max_pages_per_seq=4, chunk_max=4, attn_impl="ref")
        pc.update(pc_extra)

        def trace(engine):
            injected = []

            def _late(sched, pass_idx):
                # deterministic mid-flight admission: keyed on the pass
                # index, never the wall clock — identical on every process
                if pass_idx == 1 and not injected:
                    sched.submit(Request(late.uid, late.prompt.copy(),
                                         late.max_new))
                    injected.append(True)

            return engine.serve([Request(r.uid, r.prompt.copy(), r.max_new,
                                         r.priority) for r in reqs],
                                _late=_late)

        ref = PagedEngine(params, cfg, PagedConfig(**(ref_pc or pc)),
                          SamplerConfig(temperature=0.0))
        want = trace(ref)
        eng = PagedEngine(params, cfg, PagedConfig(**pc),
                          SamplerConfig(temperature=0.0), mesh=mesh)
        got = trace(eng)
        if ref_pc is None:
            assert eng.preemptions == ref.preemptions
        assert eng.preemptions >= min_preempt, \
            f"{tag}: wanted >= {min_preempt} preemptions, saw {eng.preemptions}"
        for uid in want:
            np.testing.assert_array_equal(got[uid], want[uid])

        h = hashlib.blake2b(digest_size=16)
        for uid in sorted(got):
            h.update(np.asarray(got[uid], np.int32).tobytes())
        for leaf in ("free_list", "page_refcounts"):
            dev = np.asarray(shardlib.host_read(eng.cache[leaf]), np.int32)
            if ref_pc is None:  # same pool shape -> byte-equal free state
                np.testing.assert_array_equal(
                    dev, np.asarray(jax.device_get(ref.cache[leaf]), np.int32))
            h.update(dev.tobytes())
        # the host allocator mirror must have replayed the identical
        # pops/pushes (PoolState is the lockstep contract)
        np.testing.assert_array_equal(
            np.asarray(shardlib.host_read(eng.cache["free_list"])),
            eng.pool_state.free_list)
        h.update(eng.pool_state.digest().encode())
        eng.assert_sampling_keys_collective_safe()

        # every process must hold the identical bytes: allgather the
        # digest (itself a cross-process collective) and compare
        local = np.frombuffer(h.digest(), np.uint8)
        gathered = np.asarray(multihost_utils.process_allgather(local))
        for other in range(gathered.shape[0]):
            np.testing.assert_array_equal(
                gathered[other], gathered[0],
                err_msg=f"{tag}: process {other} diverged")
        print(f"worker {pid}: {tag} ok "
              f"(digest {h.hexdigest()}, preemptions={eng.preemptions})")

    # cold path + mid-flight admission under the throughput policy
    battery("float+throughput",
            sched=SchedulerPolicy(admit_window=4, batch_max=2,
                                  prefill_chunk=8, watermark=(3, 6)))
    # int8 pages + shared prefixes over the same trace
    battery("int8+prefix", kv_dtype="int8", prefix_cache=True)
    # watermark preemption: short prompts over-admitted against a tight
    # pool, decode growth exhausts it mid-flight -> preempt-and-requeue;
    # the reference runs the roomy FIFO pool (preemption must not change
    # one token)
    grow = [Request(uid=50 + u,
                    prompt=rng.integers(0, 128, size=8).astype(np.int32),
                    max_new=24, priority=p) for u, p in enumerate([0, 1, 1])]
    battery("watermark-preempt", reqs=grow, min_preempt=1,
            ref_pc=dict(block_size=8, num_blocks=16, max_concurrency=3,
                        max_pages_per_seq=4, chunk_max=4, attn_impl="ref"),
            num_blocks=6,
            sched=SchedulerPolicy(admit_window=2, watermark=(1, 4)))
    print(f"worker {pid}: PASS")
    return 0


def main(argv=None) -> int:
    if os.environ.get("REPRO_MP_ROLE") == "worker":
        return worker()
    ap = argparse.ArgumentParser()
    ap.add_argument("--procs", type=int, default=2)
    ap.add_argument("--devices-per-proc", type=int, default=2)
    ap.add_argument("--port", type=int, default=29512)
    ap.add_argument("--timeout", type=float, default=900.0)
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    if args.procs < 2:
        raise SystemExit("--procs must be >= 2 (that is the point)")
    return parent(args)


if __name__ == "__main__":
    sys.exit(main())
