#!/usr/bin/env bash
# Per-family PTQ smokes + the artifact-schema smoke — one tiny end-to-end
# quantize-and-certify run per model family (dense, MoE, SSM, xLSTM,
# hybrid) through the real launcher, then pack -> validate spec -> serve.
# Shared by CI (.github/workflows/ci.yml smoke job) and local check.sh.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

for arch in tiny-lm-xs tiny-moe tiny-ssm tiny-xlstm tiny-hybrid; do
  echo "== PTQ smoke: ${arch} =="
  report=$(python -m repro.launch.quantize --arch "${arch}" \
    --calib-batches 1 --calib-batch-size 2 --seq 32 --eval-batches 1)
  echo "${report}" | python -c '
import json, sys
arch = sys.argv[1]
report = json.load(sys.stdin)
cert = report["cert"]
assert cert["ok"], f"{arch}: certification failed: {cert}"
headroom = cert["min_headroom_bits"]
ppl = report["quant_ppl"]
print(f"{arch}: certified ok, min_headroom={headroom:.4f}, quant_ppl={ppl:.2f}")
' "${arch}"
done

echo "== artifact schema smoke: pack -> validate spec -> load in engine =="
art_dir=$(mktemp -d)
trap 'rm -rf "${art_dir}"' EXIT
python -m repro.launch.quantize --arch tiny-lm-xs --algorithm rtn \
  --calib-batches 1 --calib-batch-size 2 --seq 32 --eval-batches 1 \
  --out "${art_dir}" > /dev/null
python - "${art_dir}/quantized" <<'EOF'
import sys

import jax
import numpy as np

from repro.configs import get_config
from repro.models.layers import use_packed_backend
from repro.models.transformer import init_model
from repro.quant.serve_packed import load_flat_artifact, packed_params_from_artifact
from repro.quant.spec import ARTIFACT_VERSION, DatapathSpec, tree_datapath_fingerprint
from repro.serving import GenerationEngine, PagedConfig, PagedEngine, SamplerConfig

flat, meta = load_flat_artifact(sys.argv[1])
assert meta["artifact_version"] == ARTIFACT_VERSION, meta
specs = {k: DatapathSpec.from_array(v) for k, v in flat.items() if k.endswith("/spec")}
assert specs and all(s.static_act for s in specs.values()), "sites missing static act quantizers"
cfg = get_config("tiny-lm-xs")
params = init_model(jax.random.key(0), cfg)
pp = packed_params_from_artifact(flat, params, cfg, meta=meta)
eng = GenerationEngine(pp, cfg, SamplerConfig(temperature=0.0))
prompts = np.zeros((2, 4), np.int32)
with use_packed_backend("interpret"):
    out = eng.generate(prompts, 2)
assert out.shape == (2, 6)
# the same artifact through the paged continuous-batching engine must
# produce the same greedy tokens (packed datapath under paged attention)
paged = PagedEngine(pp, cfg,
                    PagedConfig(block_size=4, num_blocks=8, max_concurrency=2,
                                max_pages_per_seq=2, attn_impl="ref"),
                    SamplerConfig(temperature=0.0))
with use_packed_backend("interpret"):
    out_paged = paged.generate(prompts, 2)
assert (out_paged == out).all(), (out_paged, out)
# and with int8 quantized KV pages: the first full-datapath configuration
# (packed W4A8 weight sites + AttnDatapathSpec-certified attention) must
# serve end-to-end with a certified record and a genuinely quantized pool.
# (Token-for-token greedy equality with float KV is asserted in tier-1 on
# briefly-TRAINED tiny models — tests/test_paged_engine.py — because on a
# random-init model near-tied argmaxes make exact equality seed luck, not
# a structural property.)
paged8 = PagedEngine(pp, cfg,
                     PagedConfig(block_size=4, num_blocks=8, max_concurrency=2,
                                 max_pages_per_seq=2, attn_impl="ref",
                                 kv_dtype="int8"),
                     SamplerConfig(temperature=0.0))
assert paged8.attn_spec is not None and paged8.attn_spec.certify()
with use_packed_backend("interpret"):
    out_paged8 = paged8.generate(prompts, 2)
assert out_paged8.shape == out.shape
pool0 = paged8.cache["pools"][0]
assert str(pool0["k_pages"].dtype) == "int8", pool0["k_pages"].dtype
assert float(np.asarray(jax.device_get(pool0["k_scales"])).max()) > 0, \
    "int8 KV pages served but no page scale was ever stamped"
# prefix cache on the packed artifact: a shared system prompt served
# through shared-prefix and fully-cached admits must reproduce the cold
# engine's greedy tokens token-for-token, and the fully-cached admit
# program must be structurally FLOP-free (no dot_general in its jaxpr)
from repro.serving import Request

pc_kw = dict(block_size=4, num_blocks=16, max_concurrency=2,
             max_pages_per_seq=4, attn_impl="ref")
pc_cold = PagedEngine(pp, cfg, PagedConfig(**pc_kw),
                      SamplerConfig(temperature=0.0))
pc_warm = PagedEngine(pp, cfg, PagedConfig(prefix_cache=True, **pc_kw),
                      SamplerConfig(temperature=0.0))
rng = np.random.default_rng(0)
system = rng.integers(0, cfg.vocab, size=8).astype(np.int32)  # 2 blocks
reqs = [Request(uid=0, max_new=4, prompt=np.concatenate(
            [system, rng.integers(0, cfg.vocab, size=3).astype(np.int32)])),
        Request(uid=1, max_new=4, prompt=system.copy()),  # fully cached
        Request(uid=2, max_new=4, prompt=np.concatenate(
            [system, rng.integers(0, cfg.vocab, size=2).astype(np.int32)]))]
with use_packed_backend("interpret"):
    pc_ref = pc_cold.serve(reqs)
    pc_out = pc_warm.serve(reqs)
for r in reqs:
    assert (pc_out[r.uid] == pc_ref[r.uid]).all(), \
        f"prefix-cache serve diverged from cold serve for uid {r.uid}"
assert pc_warm.cached_traces == 1 and pc_warm.suffix_traces >= 1
pc_warm.assert_cached_admit_flop_free()
# throughput scheduler on the packed artifact: batched admission +
# chunked prefill through the same packed datapath must reproduce the
# FIFO engine's greedy tokens for every request
from repro.serving import SchedulerPolicy

thr = PagedEngine(pp, cfg,
                  PagedConfig(block_size=4, num_blocks=16, max_concurrency=3,
                              max_pages_per_seq=4, attn_impl="ref",
                              sched=SchedulerPolicy(admit_window=3,
                                                    batch_max=2,
                                                    prefill_chunk=12)),
                  SamplerConfig(temperature=0.0))
with use_packed_backend("interpret"):
    thr_out = thr.serve([Request(uid=r.uid, prompt=r.prompt.copy(),
                                 max_new=r.max_new) for r in reqs])
for r in reqs:
    assert (thr_out[r.uid] == pc_ref[r.uid]).all(), \
        f"throughput serve diverged from FIFO serve for uid {r.uid}"
assert thr.batch_traces >= 1, "batched admission program never ran"
print(f"artifact schema ok: v{meta['artifact_version']}, {len(specs)} site specs, "
      f"datapath={tree_datapath_fingerprint(pp)}, paged decode bit-identical, "
      f"int8-KV paged serves certified [{paged8.attn_spec.describe()}], "
      f"prefix-cache serve greedy-identical "
      f"(hit_rate={pc_warm.prefix_cache.stats()['hit_rate']:.2f}, "
      f"cached admit FLOP-free), throughput serve greedy-identical "
      f"({thr.batch_traces} batched admits)")
EOF

echo "== mixed-precision smoke: search -> export -> serve identity =="
mp_dir=$(mktemp -d)
trap 'rm -rf "${art_dir}" "${mp_dir}"' EXIT
python -m repro.launch.search --arch tiny-lm-xs --p-bits 20 --tile 64 \
  --calib-batches 1 --calib-batch-size 2 --seq 32 --eval-batches 1 \
  --kv-static --out "${mp_dir}" > /dev/null
python - "${mp_dir}" <<'EOF'
import sys

import jax
import numpy as np

from repro.configs import get_config
from repro.models.transformer import init_model
from repro.quant.observe import MixedPrecisionPlan, plan_kv_scales
from repro.quant.serve_packed import (
    load_flat_artifact, packed_params_from_artifact, plan_expected_specs,
)
from repro.quant.spec import DatapathSpec, validate_datapath
from repro.serving import PagedConfig, PagedEngine, SamplerConfig

out = sys.argv[1]
plan = MixedPrecisionPlan.load(f"{out}/plan.json")
flat, meta = load_flat_artifact(f"{out}/quantized")
cfg = get_config("tiny-lm-xs")
params = init_model(jax.random.key(0), cfg)
# strict mixed-precision load + per-site datapath validation
pp = packed_params_from_artifact(flat, params, cfg, meta=meta)
base = DatapathSpec(**plan.meta["base_spec"])
n = validate_datapath(pp, plan_expected_specs(cfg, plan, base))
# the searched artifact must serve greedy-identically from disk and
# memory, with calibrated static KV scales and saturation observers on
pc = PagedConfig(block_size=4, num_blocks=8, max_concurrency=2,
                 max_pages_per_seq=2, attn_impl="ref", kv_dtype="int8")
prompts = np.zeros((2, 4), np.int32)
eng = PagedEngine(pp, cfg, pc, SamplerConfig(temperature=0.0),
                  observe=True, kv_scales=plan.kv)
out_a = eng.generate(prompts, 2)
eng.assert_observation_transparent()
rep = eng.saturation_report()
assert rep["sites"], "observer recorded no sites"
eng2 = PagedEngine(packed_params_from_artifact(flat, params, cfg, meta=meta),
                   cfg, pc, SamplerConfig(temperature=0.0),
                   kv_scales=plan_kv_scales(plan.kv))
assert (eng2.generate(prompts, 2) == out_a).all(), "reload diverged"
binding = min(rep["sites"].items(),
              key=lambda kv: kv[1].get("headroom_bits_observed", 1e9))
print(f"mixed-precision ok: {n} per-site datapaths validated "
      f"({len(plan.sites)} searched, kv={'static' if plan.kv else 'dynamic'}), "
      f"serve greedy-identical across reload, observed binding site "
      f"{binding[0]} ({binding[1].get('headroom_bits_observed', float('nan')):.2f} "
      f"headroom bits)")
EOF

echo "== smoke suite passed =="
