#!/usr/bin/env python3
"""Measure tier-1 line coverage of src/repro without pytest-cov.

The CI coverage gate (.github/workflows/ci.yml ``coverage`` job) runs
pytest-cov and fails below the recorded ``COV_FAIL_UNDER`` floor. This
script is the dependency-free local fallback that produced that baseline:
a ``sys.settrace`` line tracer restricted to ``src/repro`` frames wrapped
around the same ``-m "not slow"`` pytest run, with executable lines taken
from each file's compiled code objects (``co_lines``). It approximates
coverage.py to within a couple of points (callbacks re-entering repro
from foreign frames are pruned with their caller, undercounting slightly
— which errs the safe direction for setting a floor).

    python scripts/measure_coverage.py            # tier-1 (-m "not slow")
    python scripts/measure_coverage.py -k paged   # any extra pytest args
"""

from __future__ import annotations

import os
import sys
import threading

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src", "repro")


def executable_lines(path: str) -> set[int]:
    """Line numbers the compiler emits code for (the coverage denominator)."""
    with open(path) as f:
        source = f.read()
    lines: set[int] = set()
    stack = [compile(source, path, "exec")]
    while stack:
        code = stack.pop()
        lines.update(ln for _, _, ln in code.co_lines() if ln)
        stack.extend(c for c in code.co_consts if hasattr(c, "co_lines"))
    return lines


def main(argv: list[str]) -> int:
    sys.path.insert(0, os.path.join(ROOT, "src"))
    import pytest

    hits: dict[str, set[int]] = {}

    def tracer(frame, event, arg):
        fn = frame.f_code.co_filename
        if not fn.startswith(SRC):
            return None  # prune the whole foreign subtree
        if event == "line":
            hits.setdefault(fn, set()).add(frame.f_lineno)
        return tracer

    threading.settrace(tracer)
    sys.settrace(tracer)
    try:
        rc = pytest.main(["-q", "-m", "not slow", "-p", "no:cacheprovider",
                          *argv])
    finally:
        sys.settrace(None)
        threading.settrace(None)
    if rc != 0:
        print(f"pytest exited {rc}; coverage below is for the failed run",
              file=sys.stderr)

    total_exec = total_hit = 0
    rows = []
    for dirpath, _, files in os.walk(SRC):
        for name in sorted(files):
            if not name.endswith(".py"):
                continue
            path = os.path.join(dirpath, name)
            ex = executable_lines(path)
            hit = hits.get(path, set()) & ex
            total_exec += len(ex)
            total_hit += len(hit)
            pct = 100.0 * len(hit) / len(ex) if ex else 100.0
            rows.append((pct, os.path.relpath(path, ROOT), len(hit), len(ex)))
    rows.sort()
    print(f"\n{'file':60s} {'cover':>6s} {'lines':>11s}")
    for pct, rel, nh, ne in rows:
        print(f"{rel:60s} {pct:5.1f}% {nh:5d}/{ne:5d}")
    total = 100.0 * total_hit / max(total_exec, 1)
    print(f"\nTOTAL {total_hit}/{total_exec} = {total:.1f}% "
          f"(settrace approximation; CI gates with pytest-cov)")
    return rc


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
