#!/usr/bin/env python3
"""Bench regression gate: diff freshly produced BENCH_*.json against the
committed baselines and fail on a >25% perf regression.

    python scripts/bench_compare.py --baseline /tmp/bench_base --current .

``--baseline`` holds the *committed* BENCH_*.json snapshots (CI copies
them aside before the bench run overwrites the working tree copies).
Every numeric leaf whose key names a perf metric is compared:

* ``toks``-style keys, ``speedup`` and ``rate`` (e.g. the serving
  bench's ``prefix_cache.hit_rate`` or ``ttft_p99_speedup_vs_fifo``):
  higher is better — fail when current < baseline * (1 - threshold).
  Checked *first*: a speedup computed over a latency metric
  (``ttft_p99_speedup_vs_fifo``) must classify by what the number *is*
  (a ratio, higher-better), not by what it was computed from;
* ``us``-style keys (``us_kernel``, ``us_per_tok_paged``, ...) and the
  serving latency percentiles (``ttft_*`` / ``itl_*`` p50/p99): lower
  is better — fail when current > baseline * (1 + threshold).

Non-perf leaves (shapes, error norms, config echoes) are ignored. The
threshold defaults to 0.25 and can be widened for noisy runners via
``REPRO_BENCH_TOLERANCE``. ``--min-us`` / ``REPRO_BENCH_MIN_US`` skips
``us``-metrics where baseline AND current are both below the floor:
sub-100us single-call timings on shared/virtualized CPU swing 3-4x with
host frequency state no matter how they are measured, so noisy runners
gate only engine-scale numbers while dedicated hardware can set the
floor to 0 and the tolerance tight. A markdown table is printed either
way.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def _is_perf_key(key: str) -> str | None:
    """Classify a metric key: "lower" / "higher" better, or None (skip)."""
    parts = key.lower().replace("/", "_").split("_")
    # higher-better first: `ttft_p99_speedup_vs_fifo` is a speedup (a
    # ratio of latencies, higher-better), not a latency
    if "toks" in parts or "speedup" in parts or "rate" in parts:
        return "higher"
    if "us" in parts or "ttft" in parts or "itl" in parts:
        return "lower"
    return None


def _numeric_leaves(obj, prefix=""):
    if isinstance(obj, dict):
        for k, v in obj.items():
            yield from _numeric_leaves(v, f"{prefix}{k}" if not prefix else f"{prefix}.{k}")
    elif isinstance(obj, bool):
        return
    elif isinstance(obj, (int, float)):
        yield prefix, float(obj)


def compare_file(name: str, base: dict, cur: dict, threshold: float,
                 min_us: float = 0.0):
    """Yields (metric, baseline, current, delta, status) rows.

    Walks the union of both leaf sets: a perf metric present on one side
    only is a hard failure either way. "MISSING" (baseline leaf gone from
    the current run) catches silently dropped benches; "NO BASELINE"
    (current leaf with no committed baseline) forces every new section —
    e.g. ``mesh_serving`` — to commit its baseline in the same change
    that introduces it, or the gate cannot gate it."""
    cur_leaves = dict(_numeric_leaves(cur))
    base_leaves = dict(_numeric_leaves(base))
    for metric, c in cur_leaves.items():
        if metric in base_leaves:
            continue
        if _is_perf_key(metric.rsplit(".", 1)[-1]) is not None:
            yield metric, None, c, None, "NO BASELINE"
    for metric, b in base_leaves.items():
        direction = _is_perf_key(metric.rsplit(".", 1)[-1])
        if direction is None:
            continue
        c = cur_leaves.get(metric)
        if c is None:
            yield metric, b, None, None, "MISSING"
            continue
        if b == 0:
            continue
        delta = (c - b) / abs(b)
        if direction == "lower" and b < min_us and c < min_us:
            yield metric, b, c, delta, "below floor"
            continue
        if direction == "lower":
            status = "REGRESSED" if delta > threshold else "ok"
        else:
            status = "REGRESSED" if delta < -threshold else "ok"
        yield metric, b, c, delta, status


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--current", default=".",
                    help="directory holding the freshly produced BENCH_*.json")
    ap.add_argument("--threshold", type=float,
                    default=float(os.environ.get("REPRO_BENCH_TOLERANCE", "0.25")))
    ap.add_argument("--min-us", type=float,
                    default=float(os.environ.get("REPRO_BENCH_MIN_US", "0")))
    args = ap.parse_args(argv)

    baselines = sorted(glob.glob(os.path.join(args.baseline, "BENCH_*.json")))
    if not baselines:
        print(f"bench_compare: no BENCH_*.json under {args.baseline}", file=sys.stderr)
        return 1

    rows = []
    failures = 0
    for path in baselines:
        name = os.path.basename(path)
        cur_path = os.path.join(args.current, name)
        with open(path) as f:
            base = json.load(f)
        if not os.path.exists(cur_path):
            rows.append((name, "(file)", None, None, None, "MISSING FILE"))
            failures += 1
            continue
        with open(cur_path) as f:
            cur = json.load(f)
        for metric, b, c, delta, status in compare_file(name, base, cur,
                                                        args.threshold,
                                                        args.min_us):
            rows.append((name, metric, b, c, delta, status))
            if status in ("REGRESSED", "MISSING", "NO BASELINE"):
                failures += 1

    floor = f", us-floor {args.min_us:.0f}us" if args.min_us else ""
    print(f"\n## Bench regression check (threshold ±{args.threshold:.0%}{floor})\n")
    print("| file | metric | baseline | current | delta | status |")
    print("|---|---|---:|---:|---:|---|")
    for name, metric, b, c, delta, status in rows:
        bs = f"{b:.1f}" if isinstance(b, float) else "—"
        cs = f"{c:.1f}" if isinstance(c, float) else "—"
        ds = f"{delta:+.1%}" if isinstance(delta, float) else "—"
        print(f"| {name} | {metric} | {bs} | {cs} | {ds} | {status} |")
    compared = sum(1 for r in rows if r[5] in ("ok", "REGRESSED"))
    print(f"\n{compared} metrics compared, {failures} failure(s) "
          f"(regressed / missing / no-baseline).")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
