"""Fault-tolerance demo: train, get 'preempted', resume from the checkpoint,
and show the resumed run matches the uninterrupted one.

    PYTHONPATH=src python examples/train_and_resume.py
"""

import tempfile

from repro.launch.train import main as train_main


def main():
    with tempfile.TemporaryDirectory() as d:
        print("== uninterrupted 60-step run ==")
        _, losses_full = train_main(
            ["--arch", "tiny-lm-xs", "--steps", "60", "--batch", "8",
             "--seq", "64", "--log-every", "30"]
        )
        print("\n== first 30 steps, checkpointed ==")
        train_main(
            ["--arch", "tiny-lm-xs", "--steps", "30", "--batch", "8",
             "--seq", "64", "--ckpt-dir", d, "--ckpt-every", "30",
             "--log-every", "30"]
        )
        print("\n== resume to 60 (picks up step 30 checkpoint) ==")
        _, losses_resumed = train_main(
            ["--arch", "tiny-lm-xs", "--steps", "60", "--batch", "8",
             "--seq", "64", "--ckpt-dir", d, "--ckpt-every", "30",
             "--log-every", "30"]
        )
    print(f"\nfinal loss — uninterrupted {losses_full[-1]:.6f} vs "
          f"resumed {losses_resumed[-1]:.6f} (identical data+optimizer path)")


if __name__ == "__main__":
    main()
