"""PTQ method comparison on one model: Base (unconstrained) vs naive
bit-width manipulation vs EP-init vs AXE, at a fixed accumulator target —
the paper's §4.1 story in one script.

    PYTHONPATH=src python examples/ptq_sweep.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import PTQConfig
from repro.data import DataConfig, TokenBatcher
from repro.optim import OptimizerConfig
from repro.quant import calibrate_and_quantize
from repro.quant.pipeline import float_ppl, quantized_ppl
from repro.runtime.steps import TrainRunConfig, init_train_state, make_train_step

P_TARGET = 16


def main():
    cfg = get_config("tiny-lm-xs")
    data = TokenBatcher(DataConfig(vocab=cfg.vocab, seq_len=96, global_batch=8))
    run = TrainRunConfig(optimizer=OptimizerConfig(lr=1e-3, warmup_steps=20,
                                                   total_steps=200))
    state = init_train_state(jax.random.key(0), cfg, run)
    step = jax.jit(make_train_step(cfg, run), donate_argnums=(0,))
    for i in range(200):
        state, _ = step(state, jax.tree.map(jnp.asarray, data.batch(i)))
    params = state["params"]
    calib = [data.batch(10_000 + i) for i in range(4)]
    evalb = list(data.eval_batches(4))

    print(f"float ppl: {float_ppl(params, cfg, evalb):.2f}")
    print(f"target: signed {P_TARGET}-bit monolithic accumulator, W4A8\n")
    variants = {
        "base (no guarantee)": PTQConfig(constrain=False),
        "ep_init": PTQConfig(algorithm="ep_init", p_bits=P_TARGET, tile=None),
        "axe_hco (strict only)": PTQConfig(p_bits=P_TARGET, tile=None, soft=False),
        "axe (soft+strict)": PTQConfig(p_bits=P_TARGET, tile=None),
    }
    for name, ptq in variants.items():
        qm = calibrate_and_quantize(params, cfg, calib, ptq)
        ppl = quantized_ppl(qm, evalb)
        cert = qm.cert_summary()
        print(f"{name:24s} ppl {ppl:9.2f}   certified@P{P_TARGET}: "
              f"{cert['ok'] if ptq.constrain or ptq.algorithm == 'ep_init' else '—'}")


if __name__ == "__main__":
    main()
