"""Quickstart: train a small LM, quantize it with AXE for guaranteed 16-bit
accumulation, verify the certificate, and compare perplexity.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import PTQConfig
from repro.data import DataConfig, TokenBatcher
from repro.optim import OptimizerConfig
from repro.quant import calibrate_and_quantize
from repro.quant.pipeline import float_ppl, quantized_ppl
from repro.runtime.steps import TrainRunConfig, init_train_state, make_train_step

STEPS = 150

def main():
    cfg = get_config("tiny-lm-xs")
    data = TokenBatcher(DataConfig(vocab=cfg.vocab, seq_len=96, global_batch=8))

    # 1. train a float model on the synthetic corpus
    run = TrainRunConfig(optimizer=OptimizerConfig(lr=1e-3, warmup_steps=20,
                                                   total_steps=STEPS))
    state = init_train_state(jax.random.key(0), cfg, run)
    step = jax.jit(make_train_step(cfg, run), donate_argnums=(0,))
    for i in range(STEPS):
        state, m = step(state, jax.tree.map(jnp.asarray, data.batch(i)))
        if i % 50 == 0:
            print(f"step {i:4d}  loss {float(m['loss']):.3f}")
    params = state["params"]

    # 2. PTQ with AXE: W4A8, T=64 tiles, 16-bit inner accumulator
    ptq = PTQConfig(w_bits=4, act_bits=8, p_bits=16, tile=64, algorithm="gpfq")
    calib = [data.batch(10_000 + i) for i in range(4)]
    qm = calibrate_and_quantize(params, cfg, calib, ptq)

    # 3. the guarantee + the quality cost
    evalb = list(data.eval_batches(4))
    print("\noverflow certificate:", qm.cert_summary())
    print(f"float ppl:     {float_ppl(params, cfg, evalb):8.2f}")
    print(f"quantized ppl: {quantized_ppl(qm, evalb):8.2f}")
    print(f"naive Eq.3 bound would need P* = "
          f"{ptq.naive_p_star(cfg.d_ff)} bits; AXE certified P_I = {ptq.p_bits}")


if __name__ == "__main__":
    main()
