"""Drive the multi-pod dry-run programmatically and print a mini roofline
report for one architecture (uses a small placeholder mesh so it runs
anywhere; the production 512-chip run is `python -m repro.launch.dryrun
--all --mesh both`).

    PYTHONPATH=src python examples/distributed_dryrun.py [arch]
"""

import json
import subprocess
import sys
import tempfile
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "smollm-360m"
    with tempfile.TemporaryDirectory() as out:
        env = dict(
            os.environ,
            PYTHONPATH=os.path.join(REPO, "src"),
            REPRO_DRYRUN_XLA_FLAGS="--xla_force_host_platform_device_count=8",
        )
        for shape in ("train_4k", "decode_32k"):
            r = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                 "--shape", shape, "--mesh-shape", "2,2,2", "--out", out],
                env=env, cwd=REPO, capture_output=True, text=True,
            )
            if r.returncode != 0:
                print(r.stdout[-1500:], r.stderr[-1500:])
                raise SystemExit(f"dry-run failed for {arch}/{shape}")
            rec = json.load(open(os.path.join(out, f"{arch}__{shape}__2,2,2.json")))
            rl = rec["roofline"]
            print(f"{arch} / {shape} on (pod=2, data=2, model=2):")
            print(f"  compile: {rec['compile_s']}s  "
                  f"bytes/dev: {rec['memory']['peak_bytes_per_device']/1e9:.2f} GB")
            print(f"  roofline terms (s): compute {rl['compute_s']:.3e}  "
                  f"memory {rl['memory_s']:.3e}  collective {rl['collective_s']:.3e}")
            print(f"  dominant: {rl['dominant']}  "
                  f"useful-FLOPs ratio: {rl['useful_flops_ratio']:.3f}")
            print(f"  collectives: "
                  f"{ {k: int(v) for k, v in rec['collectives']['counts'].items() if v} }")


if __name__ == "__main__":
    main()
