"""Serve a quantized model: batched greedy generation through the
simulated-integer path, plus one layer pushed through the real Pallas W4A8
kernel (interpret mode on CPU, compiled on TPU).

    PYTHONPATH=src python examples/serve_quantized.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import PTQConfig
from repro.core.quantizers import quantize_act
from repro.data import DataConfig, TokenBatcher
from repro.kernels import pack_int4, quantized_linear_w4a8
from repro.models.transformer import init_model
from repro.quant import calibrate_and_quantize, quantized_forward


def main():
    cfg = get_config("tiny-lm-xs")
    params = init_model(jax.random.key(0), cfg)
    data = TokenBatcher(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4))
    ptq = PTQConfig(w_bits=4, act_bits=8, p_bits=16, tile=64)
    qm = calibrate_and_quantize(params, cfg, [data.batch(i) for i in range(2)], ptq)
    print("certificate:", qm.cert_summary())

    # batched greedy generation with the quantized model (sim path)
    prompts = np.asarray(data.batch(99)["tokens"])[:, :16]
    toks = jnp.asarray(prompts)
    t0 = time.time()
    for _ in range(16):
        logits = quantized_forward(qm, {"tokens": toks})
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        toks = jnp.concatenate([toks, nxt], axis=1)
    print(f"generated {16 * toks.shape[0]} tokens in {time.time()-t0:.2f}s")
    print("sample:", np.asarray(toks[0, -16:]).tolist())

    # one linear through the real integer kernel
    b0 = qm.blocks[0]
    x = jax.random.normal(jax.random.key(1), (64, cfg.d_model))
    codes = jnp.asarray(quantize_act(x, b0.wq.act), jnp.uint8)
    packed = pack_int4(jnp.asarray(np.asarray(b0.wq.q_int, np.int8)))
    y = quantized_linear_w4a8(codes, packed, b0.wq.scale[0],
                              b0.wq.act.scale, b0.wq.act.zero_point,
                              block_m=64, block_n=64, block_k=64)
    print("pallas w4a8 output:", y.shape, "finite:", bool(jnp.all(jnp.isfinite(y))))

    # the certified serving datapath travels with the artifact: build the
    # packed serving tree (static act quantizers, per-site DatapathSpec)
    # and run the real generation engine on it — no kwargs re-specified
    from repro.models.layers import use_packed_backend
    from repro.quant.serve_packed import serving_params_from_quantized
    from repro.serving import GenerationEngine, SamplerConfig

    print("wq datapath:", b0.wq.spec.describe())
    sp = serving_params_from_quantized(qm)
    eng = GenerationEngine(sp, cfg, SamplerConfig(temperature=0.0))
    with use_packed_backend("interpret"):  # fused W4A8 kernel, CPU-validated
        out = eng.generate(prompts[:, :8], 8)
    print("engine sample (certified datapath", eng.datapath_fingerprint + "):",
          out[0, 8:].tolist())


if __name__ == "__main__":
    main()
