"""Paper Table 3: monolithic P_O=16 vs multi-stage (T=64, P_I=16) scaling.
The paper's claim: the monolithic budget tightens as width K grows (quality
collapses up the ladder) while fixed-P_I multi-stage holds."""

from __future__ import annotations

from repro.core import PTQConfig

from .common import (
    FAST,
    calib_batches,
    csv_row,
    eval_batches,
    quantize_and_eval,
    trained_params,
)

LADDER = ["tiny-lm-xs", "tiny-lm-s", "tiny-lm-m", "tiny-lm-l"]
if FAST:
    LADDER = ["tiny-lm-xs", "tiny-lm-s"]


def run(algorithms=("gpfq", "optq")):
    results = {}
    for arch in LADDER:
        cfg, params = trained_params(arch)
        calib = calib_batches(cfg)
        evalb = eval_batches(cfg)
        for alg in algorithms:
            mono = quantize_and_eval(
                cfg, params, PTQConfig(algorithm=alg, p_bits=16, tile=None),
                calib, evalb,
            )
            multi = quantize_and_eval(
                cfg, params, PTQConfig(algorithm=alg, p_bits=16, tile=64),
                calib, evalb,
            )
            results[(arch, alg)] = (mono["ppl"], multi["ppl"])
            csv_row(f"table3/{arch}/{alg}/monolithic16", mono["quantize_s"] * 1e6,
                    f"ppl={mono['ppl']:.2f}")
            csv_row(f"table3/{arch}/{alg}/64x16b", multi["quantize_s"] * 1e6,
                    f"ppl={multi['ppl']:.2f};ratio_mono_over_multi="
                    f"{mono['ppl'] / multi['ppl']:.2f}")
    return results


if __name__ == "__main__":
    run()
