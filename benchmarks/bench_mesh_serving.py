"""Mesh-sharded paged serving vs the 1-device engine on the same trace.

Needs more than one visible device — CI runs it in a dedicated step with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (on one real
device the bench prints a skip note and writes nothing, so a plain local
``python -m benchmarks.run`` still completes). On fake CPU devices the
sharded engine is *slower* than one device — every decode chunk pays
emulated collectives for a model that fits in L2 — so the gated number is
not a speedup but the overhead ratio ``toks_ratio_sharded_vs_1dev``:
a step-change drop means the SPMD path started paying per-token resharding
or extra host syncs (the regression class the one-``device_get``-per-chunk
rule exists to prevent). Greedy streams are asserted bit-identical between
the two engines before any number is reported. Writes
``BENCH_mesh_serving.json``.
"""

from __future__ import annotations

import time

import numpy as np

import jax

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models.transformer import init_model
from repro.serving import PagedEngine, SamplerConfig

from .bench_serving import ARCH, make_paged_engine, make_trace
from .common import FAST, csv_row, write_bench_json

REPS = 3 if FAST else 5


def _timed(eng, vocab) -> tuple[float, dict]:
    out = eng.serve(make_trace(vocab))  # warm every jit bucket
    best = float("inf")
    for _ in range(REPS):
        t0 = time.time()
        eng.serve(make_trace(vocab))
        best = min(best, time.time() - t0)
    return best, out


def run():
    n_dev = len(jax.devices())
    if n_dev < 2:
        print("bench/mesh_serving/skip,0,needs >= 2 devices "
              "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        return None
    mesh = make_mesh((n_dev // 2, 2))
    cfg = get_config(ARCH)
    params = init_model(jax.random.key(0), cfg)
    reqs = make_trace(cfg.vocab)
    useful = sum(r.max_new for r in reqs)

    ref = make_paged_engine(params, cfg, reqs)
    dt_ref, want = _timed(ref, cfg.vocab)
    sharded = PagedEngine(params, cfg, ref.paged,
                          SamplerConfig(temperature=0.0), mesh=mesh)
    dt_sh, got = _timed(sharded, cfg.vocab)
    for r in reqs:  # identity first, numbers second
        np.testing.assert_array_equal(got[r.uid], want[r.uid])

    toks_ref = useful / dt_ref
    toks_sh = useful / dt_sh
    results = {
        "backend": jax.default_backend(),
        "arch": ARCH,
        "devices": n_dev,
        "mesh_shape": list(mesh.devices.shape),
        "useful_tokens": useful,
        "toks_1dev": toks_ref,
        "toks_sharded": toks_sh,
        "toks_ratio_sharded_vs_1dev": toks_sh / toks_ref,
        "us_per_tok_sharded": 1e6 * dt_sh / useful,
    }
    csv_row(f"mesh_serving/{'fast' if FAST else 'full'}",
            results["us_per_tok_sharded"],
            f"sharded={toks_sh:.1f}toks;1dev={toks_ref:.1f}toks;"
            f"ratio={toks_sh / toks_ref:.2f}x@{n_dev}dev")
    write_bench_json("BENCH_mesh_serving.json", results)
    return results


if __name__ == "__main__":
    run()
