"""Per-family PTQ end-to-end: calibrate+quantize+certify+eval one tiny rung
of every registered model family (dense, MoE, SSM, xLSTM, and a Jamba-style
hybrid) under the paper's default W4A8 / T=128 / P_I=16 recipe.

The table answers two questions the dense-only benches cannot: does the
accumulator constraint certify on every family's site set, and what does
the constraint cost in perplexity relative to the float model per family.
"""

from __future__ import annotations

import jax

from repro.configs import get_config
from repro.core import PTQConfig
from repro.models.transformer import init_model

from .common import (
    FAST,
    baseline_float_ppl,
    calib_batches,
    csv_row,
    eval_batches,
    quantize_and_eval,
)

FAMILY_LADDER = ["tiny-lm-xs", "tiny-moe", "tiny-ssm", "tiny-xlstm", "tiny-hybrid"]
if FAST:
    FAMILY_LADDER = ["tiny-lm-xs", "tiny-moe", "tiny-ssm"]


def run():
    results = {}
    for arch in FAMILY_LADDER:
        cfg = get_config(arch)
        # recurrent/MoE rungs are scored from a fixed float init (the bench
        # isolates quantization quality, not training quality)
        params = init_model(jax.random.key(0), cfg)
        calib = calib_batches(cfg)
        evalb = eval_batches(cfg)
        ppl_f = baseline_float_ppl(cfg, params, evalb)
        r = quantize_and_eval(cfg, params, PTQConfig(), calib, evalb)
        results[arch] = r
        csv_row(
            f"families/{arch}/w4a8_t128_p16",
            r["quantize_s"] * 1e6,
            f"certified={r['certified']};min_headroom={r['min_headroom']:.4f};"
            f"ppl_ratio={r['ppl'] / ppl_f:.3f};sparsity={r['sparsity']:.3f}",
        )
    return results


if __name__ == "__main__":
    run()
