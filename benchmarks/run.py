"""Benchmark driver — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table1,table2,...]

Prints ``name,us_per_call,derived`` CSV. Set REPRO_BENCH_FAST=1 for the
reduced grids (CI).
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = ("pareto", "table1", "table2", "table3", "kernels", "roofline",
           "families", "decode", "datapath", "serving", "mesh_serving")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help=f"comma-separated subset of {BENCHES}")
    args = ap.parse_args(argv)
    selected = args.only.split(",") if args.only else list(BENCHES)

    print("name,us_per_call,derived")
    failures = 0
    for name in selected:
        t0 = time.time()
        try:
            if name == "pareto":
                from . import bench_pareto

                bench_pareto.run()
            elif name == "table1":
                from . import bench_multistage

                bench_multistage.run()
            elif name == "table2":
                from . import bench_ablation

                bench_ablation.run()
            elif name == "table3":
                from . import bench_monolithic

                bench_monolithic.run()
            elif name == "kernels":
                from . import bench_kernels

                bench_kernels.run()
            elif name == "families":
                from . import bench_families

                bench_families.run()
            elif name == "decode":
                from . import bench_decode

                bench_decode.run()
            elif name == "datapath":
                from . import bench_datapath

                bench_datapath.run()
            elif name == "serving":
                from . import bench_serving

                bench_serving.run()
            elif name == "mesh_serving":
                from . import bench_mesh_serving

                bench_mesh_serving.run()
            elif name == "roofline":
                from . import bench_roofline

                bench_roofline.run()
            else:
                raise ValueError(f"unknown bench {name}")
            print(f"bench/{name}/wall,{(time.time() - t0) * 1e6:.0f},ok")
        except Exception as e:  # a failing table must not hide the others
            failures += 1
            traceback.print_exc()
            print(f"bench/{name}/wall,{(time.time() - t0) * 1e6:.0f},"
                  f"FAIL:{type(e).__name__}:{e}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
