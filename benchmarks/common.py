"""Shared benchmark infrastructure.

Models are trained once on the deterministic synthetic corpus and cached on
disk (benchmarks/_cache); every table then quantizes from the same float
checkpoints, exactly like the paper quantizes the same pretrained models
under different configs.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.configs import get_config
from repro.core import PTQConfig
from repro.data import DataConfig, TokenBatcher
from repro.models.transformer import init_model
from repro.optim import OptimizerConfig
from repro.quant import calibrate_and_quantize
from repro.quant.pipeline import float_ppl, quantized_ppl
from repro.runtime.steps import TrainRunConfig, init_train_state, make_train_step

CACHE = os.path.join(os.path.dirname(os.path.abspath(__file__)), "_cache")
FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"

TRAIN_STEPS = 120 if FAST else 400
SEQ = 96
BATCH = 8
CALIB_BATCHES = 2 if FAST else 4
EVAL_BATCHES = 2 if FAST else 4


def data_for(cfg):
    return TokenBatcher(
        DataConfig(vocab=cfg.vocab, seq_len=SEQ, global_batch=BATCH, seed=7)
    )


def trained_params(arch: str):
    """Train (or load cached) float params for a tiny-lm rung."""
    cfg = get_config(arch)
    path = os.path.join(CACHE, f"{arch}_s{TRAIN_STEPS}")
    template = jax.eval_shape(lambda k: init_model(k, cfg), jax.random.key(0))
    if os.path.exists(os.path.join(path, "manifest.json")):
        params, _ = load_pytree(template, path)
        return cfg, params

    run = TrainRunConfig(
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=20, total_steps=TRAIN_STEPS)
    )
    state = init_train_state(jax.random.key(0), cfg, run)
    step = jax.jit(make_train_step(cfg, run), donate_argnums=(0,))
    data = data_for(cfg)
    t0 = time.time()
    for i in range(TRAIN_STEPS):
        state, m = step(state, jax.tree.map(jnp.asarray, data.batch(i)))
    print(f"# trained {arch}: {TRAIN_STEPS} steps in {time.time()-t0:.0f}s "
          f"final loss {float(m['loss']):.3f}")
    save_pytree(state["params"], path)
    return cfg, state["params"]


def eval_batches(cfg):
    return list(data_for(cfg).eval_batches(EVAL_BATCHES))


def calib_batches(cfg):
    d = data_for(cfg)
    return [d.batch(50_000 + i) for i in range(CALIB_BATCHES)]


def quantize_and_eval(cfg, params, ptq: PTQConfig, calib=None, evalb=None):
    calib = calib or calib_batches(cfg)
    evalb = evalb or eval_batches(cfg)
    t0 = time.time()
    qm = calibrate_and_quantize(params, cfg, calib, ptq)
    dt = time.time() - t0
    ppl = quantized_ppl(qm, evalb)
    return {
        "ppl": ppl,
        "certified": qm.certified,
        "min_headroom": qm.cert_summary()["min_headroom_bits"],
        "quantize_s": dt,
        "sparsity": _sparsity(qm),
    }


def _sparsity(qm) -> float:
    z, n = 0, 0
    for _, ql in qm.quantized_linears():
        q = np.asarray(ql.q_int)
        z += (q == 0).sum()
        n += q.size
    return float(z) / max(n, 1)


def baseline_float_ppl(cfg, params, evalb=None):
    return float_ppl(params, cfg, evalb or eval_batches(cfg))


def poisson_trace(n: int, rate: float, seed: int, *, prompt_lens,
                  max_news, priorities=(0,), vocab: int = 128,
                  uid_base: int = 0):
    """Seeded Poisson arrival trace shared by ``bench_serving.py`` and the
    scheduler property tests — byte-for-byte reproducible (one
    ``PCG64``-seeded Generator drives arrivals, lengths, priorities and
    prompt tokens; no wall clock, no global state), so CI and local runs
    replay the identical workload.

    Returns ``(requests, arrivals)``: ``n`` request dicts
    ``{uid, prompt, max_new, priority}`` in arrival order and their
    cumulative arrival times (seconds, exponential gaps at ``rate``
    req/s). Returned as plain dicts so the tests can wrap them in
    ``Request`` while the bench reuses one trace across engines."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(n):
        s0 = int(rng.choice(prompt_lens))
        reqs.append({
            "uid": uid_base + i,
            "prompt": rng.integers(0, vocab, size=s0).astype(np.int32),
            "max_new": int(rng.choice(max_news)),
            "priority": int(rng.choice(priorities)),
        })
    return reqs, arrivals.tolist()


def trace_digest(reqs, arrivals) -> str:
    """Stable digest of a :func:`poisson_trace` (pinned in the tests: the
    generator must stay byte-for-byte reproducible or the committed
    latency baselines silently measure a different workload)."""
    import hashlib

    h = hashlib.blake2b(digest_size=16)
    for r, t in zip(reqs, arrivals):
        h.update(np.int64(r["uid"]).tobytes())
        h.update(np.asarray(r["prompt"], np.int32).tobytes())
        h.update(np.int64(r["max_new"]).tobytes())
        h.update(np.int64(r["priority"]).tobytes())
        h.update(np.float64(t).tobytes())
    return h.hexdigest()


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def time_min(fn, reps: int = 5) -> float:
    """Min-of-reps latency: the noise-robust estimator (scheduler jitter
    and frequency scaling only ever make a rep slower, never faster), so
    the scripts/bench_compare.py regression gate sees a stable per-box
    number. Sub-millisecond calls are batched (~20ms per rep, capped at
    200 calls) so one dispatch hiccup cannot dominate the measurement.
    One timing methodology for every bench that feeds the gate."""
    fn()  # warm (jit compile)
    t0 = time.time()
    fn()
    probe = time.time() - t0
    inner = max(1, min(200, int(0.02 / max(probe, 1e-7))))
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        for _ in range(inner):
            fn()
        best = min(best, (time.time() - t0) / inner)
    return best


def write_bench_json(path: str, results: dict) -> None:
    """Write ``results`` under the active grid's section ("fast" when
    REPRO_BENCH_FAST=1, "full" otherwise), merging with any existing
    file — a full-grid run must never clobber the committed FAST-grid
    baselines the CI regression gate compares against (and vice versa)."""
    import json

    grid = "fast" if FAST else "full"
    try:
        with open(path) as f:
            merged = json.load(f)
    except (FileNotFoundError, ValueError):
        merged = {}
    if not ("fast" in merged or "full" in merged or not merged):
        merged = {}  # legacy flat schema: start over with grid sections
    merged[grid] = results
    with open(path, "w") as f:
        json.dump(merged, f, indent=2)
