"""Paper Table 2: error correction / rounding / soft-constraint ablation —
EP-init vs AXE-RTZ vs AXE-RTN vs AXE-HCO at W4A8 with a binding monolithic
accumulator target."""

from __future__ import annotations

from repro.core import PTQConfig

from .common import (
    FAST,
    baseline_float_ppl,
    calib_batches,
    csv_row,
    eval_batches,
    quantize_and_eval,
    trained_params,
)

MODELS = ["tiny-lm-s"] if FAST else ["tiny-lm-s", "tiny-lm-m"]
P_TARGET = 16  # binding for K in [128, 768] at W4A8 (B ~ 128.5 l1 budget)

VARIANTS = {
    "ep_init": dict(algorithm="ep_init"),
    "axe_rtz": dict(rounding="zero"),
    "axe_rtn": dict(rounding="nearest"),
    "axe_hco": dict(rounding="nearest", soft=False),
}


def run(algorithms=("gpfq", "optq")):
    results = {}
    for arch in MODELS:
        cfg, params = trained_params(arch)
        calib = calib_batches(cfg)
        evalb = eval_batches(cfg)
        csv_row(f"table2/{arch}/float", 0.0,
                f"ppl={baseline_float_ppl(cfg, params, evalb):.2f}")
        for alg in algorithms:
            for name, fields in VARIANTS.items():
                f = dict(fields)
                if name != "ep_init":
                    f["algorithm"] = alg
                elif alg == "optq":
                    continue
                ptq = PTQConfig(p_bits=P_TARGET, tile=None, **f)
                res = quantize_and_eval(cfg, params, ptq, calib, evalb)
                results[(arch, alg, name)] = res["ppl"]
                csv_row(
                    f"table2/{arch}/{alg}/{name}",
                    res["quantize_s"] * 1e6,
                    f"ppl={res['ppl']:.2f};cert={res['certified']}",
                )
    return results


if __name__ == "__main__":
    run()
