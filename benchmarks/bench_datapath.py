"""Datapath sweep: the same packed site served under different certified
accumulation datapaths (T, P_I) — the DatapathSpec drives the kernel's
K-tile size and inner accumulator width with no call-site kwargs.

Sweeps (T, P_I) ∈ {(64, 12), (128, 16), (256, 20)} over one decode-shaped
site and reports:

  * us/call for the fused kernel path (interpret mode on CPU — a
    *validity* probe, not a speed claim; compiled timing only means
    anything on TPU hardware) and the dequant fallback baseline;
  * max |err| of the spec-driven kernel vs the dequant reference;
  * the Eq. 22 outer-accumulator width the spec certifies at this depth;
  * static-vs-dynamic activation quantization us/call at the same site
    (the serving-time win of shipping calibrated act quantizers in the
    artifact).

Writes ``BENCH_datapath.json`` (cwd) so the datapath trajectory is tracked
per PR, and prints the usual csv rows.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.alphabet import outer_accumulator_bits
from repro.models.layers import packed_linear, use_packed_backend
from repro.quant.serve_packed import _pack_leaf
from repro.quant.spec import DatapathSpec

from .common import FAST, csv_row, time_min, write_bench_json

SWEEP = ((64, 12), (128, 16), (256, 20))
K, N = (512, 128) if FAST else (512, 512)
BATCH = 2 if FAST else 4
REPS = 5 if FAST else 7


def _time(fn, reps: int = REPS) -> float:
    return time_min(fn, reps)


def run():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(BATCH, K)), jnp.float32)
    results = {"backend": jax.default_backend(), "K": K, "N": N,
               "batch": BATCH, "sweep": {}}

    for tile, p_inner in SWEEP:
        spec = DatapathSpec(tile=tile, p_inner=p_inner,
                            p_outer=outer_accumulator_bits(p_inner, K, tile))
        leaf = _pack_leaf(w, spec)

        @jax.jit
        def kernel_mm(x, leaf=leaf):
            with use_packed_backend("interpret"):
                return packed_linear(x, leaf)

        @jax.jit
        def dequant_mm(x, leaf=leaf):
            with use_packed_backend("dequant"):
                return packed_linear(x, leaf)

        us_kernel = _time(lambda: jax.block_until_ready(kernel_mm(x))) * 1e6
        us_dequant = _time(lambda: jax.block_until_ready(dequant_mm(x))) * 1e6
        err = float(jnp.max(jnp.abs(kernel_mm(x) - dequant_mm(x))))
        key = f"T{tile}_PI{p_inner}"
        results["sweep"][key] = {
            "tile": tile,
            "p_inner": p_inner,
            "p_outer": spec.p_outer,
            "us_kernel": us_kernel,
            "us_dequant": us_dequant,
            "max_abs_err": err,
        }
        csv_row(
            f"datapath/{key}",
            us_kernel,
            f"p_outer={spec.p_outer};dequant_us={us_dequant:.1f};"
            f"max_abs_err={err:.4f}",
        )

    # static vs dynamic activation quantization on the recipe datapath.
    # The static node AND the spec_arr array twin are both rebuilt so the
    # leaf stays internally consistent (the twin is authoritative across
    # array-only round trips — see serve_packed.ensure_datapath_spec).
    from repro.quant.serve_packed import _spec_arr_leaf

    dyn_leaf = _pack_leaf(w, DatapathSpec())
    stat_spec = DatapathSpec().with_act(float(jnp.max(jnp.abs(x)) / 127.5), 128)
    stat_leaf = dict(dyn_leaf)
    stat_leaf["spec"] = stat_spec.leaf_spec()
    stat_leaf["spec_arr"] = _spec_arr_leaf(stat_spec, ())
    stat_leaf["act_scale"] = jnp.asarray(stat_spec.act_scale, jnp.float32)
    stat_leaf["act_zp"] = jnp.asarray(float(stat_spec.act_zp), jnp.float32)

    def act_probe(leaf):
        @jax.jit
        def mm(x):
            with use_packed_backend("interpret"):
                return packed_linear(x, leaf)

        return _time(lambda: jax.block_until_ready(mm(x))) * 1e6

    us_dyn, us_stat = act_probe(dyn_leaf), act_probe(stat_leaf)
    results["act_quant"] = {"us_dynamic": us_dyn, "us_static": us_stat}
    csv_row("datapath/act_quant", us_stat,
            f"dynamic_us={us_dyn:.1f};static_us={us_stat:.1f}")

    # uniform-vs-searched mixed-precision frontier: lives in the datapath
    # bench (not the pareto table) so the CI subset — decode, datapath,
    # serving — gates it on every PR via scripts/bench_compare.py
    from .bench_pareto import mixed_frontier, sparse_frontier

    results["mixed_frontier"] = mixed_frontier()
    # 2:4 arm: same gating story — the sparse point's certificate-floor
    # and quality invariants collapse to *_rate keys the compare script
    # hard-fails on (NO BASELINE forces this section to ship with its
    # committed baseline)
    results["sparse_frontier"] = sparse_frontier()

    write_bench_json("BENCH_datapath.json", results)
    return results


if __name__ == "__main__":
    run()
