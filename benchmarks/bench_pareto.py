"""Paper Figures 1 & 3 / Tables 4-7: the accumulator-bit-width vs quality
Pareto frontier, PTQ setting.

For each (M, N) in the design space and each method:
  * naive bit-width manipulation: quantize unconstrained at (M, N); its
    guaranteed accumulator is P* from Eq. 3;
  * EP-init: l1 projection + RTZ at target P (A2Q+ applied post-hoc);
  * AXE: constrained GPFQ/OPTQ at target P.
The frontier reports the best perplexity per accumulator width.
"""

from __future__ import annotations

from repro.core import PTQConfig
from repro.quant import calibrate_and_quantize
from repro.quant.observe import (
    apply_plan,
    collect_observations,
    plan_accumulator_bits,
    search_plan,
)
from repro.quant.pipeline import quantized_ppl

from .common import (
    FAST,
    baseline_float_ppl,
    calib_batches,
    csv_row,
    eval_batches,
    quantize_and_eval,
    trained_params,
)

ARCH = "tiny-lm-s"
MN_GRID = [(3, 4), (4, 4), (4, 6), (4, 8), (6, 8), (8, 8)]
P_GRID = [12, 13, 14, 15, 16, 18, 20]
if FAST:
    MN_GRID = [(4, 4), (4, 8)]
    P_GRID = [14, 16, 20]


def run(algorithms=("gpfq", "optq")):
    cfg, params = trained_params(ARCH)
    calib = calib_batches(cfg)
    evalb = eval_batches(cfg)
    fppl = baseline_float_ppl(cfg, params, evalb)
    csv_row(f"pareto/{ARCH}/float", 0.0, f"ppl={fppl:.2f}")

    rows = []
    for alg in algorithms:
        # naive manipulation: unconstrained, P = P*(M, N, K_max)
        k_max = max(cfg.d_model, cfg.d_ff)
        for m, n in MN_GRID:
            ptq = PTQConfig(w_bits=m, act_bits=n, algorithm=alg, constrain=False)
            res = quantize_and_eval(cfg, params, ptq, calib, evalb)
            p_star = ptq.naive_p_star(k_max)
            rows.append((alg, "naive", p_star, m, n, res))
            csv_row(
                f"pareto/{ARCH}/{alg}/naive/M{m}N{n}",
                res["quantize_s"] * 1e6,
                f"P*={p_star};ppl={res['ppl']:.2f};sparsity={res['sparsity']:.3f}",
            )
        for method, fields in (
            ("ep_init", dict(algorithm="ep_init")),
            ("axe", dict(algorithm=alg, constrain=True)),
        ):
            if method == "ep_init" and alg == "optq":
                continue  # EP-init is algorithm-independent; emit once
            for p in P_GRID:
                for m, n in MN_GRID:
                    try:
                        ptq = PTQConfig(w_bits=m, act_bits=n, p_bits=p,
                                        tile=None, **fields)
                        res = quantize_and_eval(cfg, params, ptq, calib, evalb)
                    except ValueError:
                        continue  # P too small for N (Eq. 21 infeasible)
                    rows.append((alg, method, p, m, n, res))
                    csv_row(
                        f"pareto/{ARCH}/{alg}/{method}/P{p}M{m}N{n}",
                        res["quantize_s"] * 1e6,
                        f"ppl={res['ppl']:.2f};cert={res['certified']};"
                        f"sparsity={res['sparsity']:.3f}",
                    )

    # frontier: best ppl at accumulator width <= P
    for alg in algorithms:
        for method in ("naive", "ep_init", "axe"):
            pts = [
                (p, r["ppl"])
                for a, meth, p, _, _, r in rows
                if meth == method and (a == alg or method == "ep_init")
            ]
            if not pts:
                continue
            frontier = {}
            for p, ppl in sorted(pts):
                best = min(ppl, frontier.get(p, float("inf")))
                frontier[p] = best
            running = float("inf")
            for p in sorted(frontier):
                running = min(running, frontier[p])
                csv_row(f"pareto_frontier/{ARCH}/{alg}/{method}/P{p}", 0.0,
                        f"best_ppl={running:.2f}")
    return rows


def mixed_frontier(p_uniform: int = 20):
    """Uniform-vs-searched accumulator/quality frontier point.

    Calibrates the uniform AXE baseline at a *conservative* register
    (constrained GPFQ at a tight register shapes codes to fill it —
    the per-site slack below ``p_uniform`` is what the search reclaims),
    then runs the headroom-driven per-site search and the
    certificate-exact re-spec. Because P_I-only moves serve the *same*
    codes, the searched point dominates the uniform one by construction:
    strictly fewer global accumulator bits at bit-identical perplexity.

    The ``*_rate`` keys feed scripts/bench_compare.py (higher-better):
    ``frontier_dominates_rate`` collapses the dominance invariant to
    1.0/0.0 so any future regression (empty plan, lost certificate,
    perplexity drift) trips the gate outright rather than hiding inside
    the tolerance band.
    """
    cfg, params = trained_params(ARCH)
    calib = calib_batches(cfg)
    evalb = eval_batches(cfg)
    ptq = PTQConfig(w_bits=4, act_bits=8, p_bits=p_uniform, tile=None,
                    algorithm="gpfq", constrain=True)
    qm = calibrate_and_quantize(params, cfg, calib, ptq)
    report = collect_observations(qm)
    plan = search_plan(report)
    qm2 = apply_plan(qm, plan)

    uniform_bits = report.accumulator_bits()
    searched_bits = plan_accumulator_bits(plan, report)
    ppl_u = quantized_ppl(qm, evalb)
    ppl_s = quantized_ppl(qm2, evalb)
    dominates = searched_bits < uniform_bits and ppl_s <= ppl_u and qm2.certified
    res = {
        "arch": ARCH,
        "p_uniform": p_uniform,
        "uniform_acc_bits": uniform_bits,
        "searched_acc_bits": searched_bits,
        "acc_budget_savings_rate": uniform_bits / max(searched_bits, 1),
        "ppl_uniform": ppl_u,
        "ppl_searched": ppl_s,
        "ppl_guard_rate": ppl_u / ppl_s,
        "frontier_dominates_rate": 1.0 if dominates else 0.0,
        "n_planned_sites": len(plan),
        "binding_site": report.binding_site(),
    }
    csv_row(
        f"pareto_mixed/{ARCH}/P{p_uniform}", 0.0,
        f"uniform_bits={uniform_bits};searched_bits={searched_bits};"
        f"ppl_u={ppl_u:.2f};ppl_s={ppl_s:.2f};dominates={dominates}",
    )
    return res


#: quality guard for the sparse arm: pruning half the weights of the N
#: most-headroomed sites must not blow perplexity past this factor of the
#: dense point (mask-aware GPFQ redistributes the pruned energy; a broken
#: error-feedback path fails this outright, not by a tolerance band)
SPARSE_PPL_GUARD = 1.5


def sparse_frontier(p_uniform: int = 20, n_sparsify: int = 2):
    """2:4 semi-structured sparsity arm of the accumulator frontier.

    Starts from the same conservative uniform AXE baseline as
    :func:`mixed_frontier`, asks the search to mark the ``n_sparsify``
    most-headroomed eligible sites for 2:4 sparsity, and drives the
    mask-aware re-calibration the code-changing move requires. Reports
    the post-recalibration certificate floors of the sparsified sites
    against their dense floors — the certificate is issued against the
    halved effective depth (docs/datapath.md), so the sparse floor can
    never exceed the dense one.

    The ``*_rate`` keys feed scripts/bench_compare.py (higher-better) and
    collapse the invariants to hard 1.0/0.0 indicators:

    * ``floor_tightens_rate``: every sparsified site's certificate floor
      is <= its dense floor (the accumulator-side win);
    * ``ppl_guard_rate``: the sparse point stays certified, sparsifies
      exactly the requested sites, and holds perplexity within
      ``SPARSE_PPL_GUARD`` of dense (the quality-side guard).
    """
    cfg, params = trained_params(ARCH)
    calib = calib_batches(cfg)
    evalb = eval_batches(cfg)
    ptq = PTQConfig(w_bits=4, act_bits=8, p_bits=p_uniform, tile=None,
                    algorithm="gpfq", constrain=True)
    qm = calibrate_and_quantize(params, cfg, calib, ptq)
    report = collect_observations(qm)
    plan = search_plan(report, sparsify=n_sparsify)
    # sparsity changes the codes: mask-aware constrained re-solve, not a
    # re-spec of the dense codes
    qm2 = calibrate_and_quantize(params, cfg, calib, ptq, plan=plan)
    report2 = collect_observations(qm2)

    names = plan.meta["sparsified"]
    dense_floors = {n: report.sites[n].p_floor for n in names}
    sparse_floors = {n: report2.sites[n].p_floor for n in names}
    floor_tightens = all(sparse_floors[n] <= dense_floors[n] for n in names)
    saving = sum(dense_floors[n] - sparse_floors[n] for n in names)
    ppl_d = quantized_ppl(qm, evalb)
    ppl_s = quantized_ppl(qm2, evalb)
    guarded = (
        qm2.certified
        and len(names) == n_sparsify
        and ppl_s <= ppl_d * SPARSE_PPL_GUARD
    )
    res = {
        "arch": ARCH,
        "p_uniform": p_uniform,
        "n_sparsified": len(names),
        "sparsified_sites": names,
        "dense_floor_bits": sum(dense_floors.values()),
        "sparse_floor_bits": sum(sparse_floors.values()),
        "floor_saving_bits": saving,
        "ppl_dense": ppl_d,
        "ppl_sparse": ppl_s,
        "floor_tightens_rate": 1.0 if floor_tightens else 0.0,
        "ppl_guard_rate": 1.0 if guarded else 0.0,
    }
    csv_row(
        f"pareto_sparse/{ARCH}/P{p_uniform}x{n_sparsify}", 0.0,
        f"sites={len(names)};floor_saving_bits={saving};"
        f"ppl_d={ppl_d:.2f};ppl_s={ppl_s:.2f};guarded={guarded}",
    )
    return res


if __name__ == "__main__":
    run()
    mixed_frontier()
    sparse_frontier()
