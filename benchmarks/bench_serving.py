"""Serving throughput under a skewed-length request trace: fixed-slot
batching vs paged-KV continuous batching.

The trace models production traffic: request lengths drawn from a skewed
distribution (most sequences short, a heavy tail long — the shape that
motivated paged attention in production servers). The fixed-slot baseline
processes the trace in arrival-order batches of ``CONCURRENCY``: prompts
pad to the batch max and every slot decodes until the batch's *longest*
request finishes — the slot-idling pathology. The paged engine runs the
same trace through the continuous-batching scheduler: a finished sequence
frees its pages and its slot is refilled mid-flight.

Throughput counts *useful* tokens only (each request's own max_new), so
the fixed-slot engine gets no credit for decoding padding slots. Writes
``BENCH_serving.json``; the CI regression gate (scripts/bench_compare.py)
tracks the tok/s numbers and the speedup.
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs import get_config
from repro.models.transformer import init_model
from repro.serving import (
    GenerationEngine,
    PagedConfig,
    PagedEngine,
    Request,
    SamplerConfig,
)

from .common import FAST, csv_row, write_bench_json

import jax

ARCH = "tiny-lm-xs"
CONCURRENCY = 8
if FAST:
    N_REQ = 8
    PROMPT_LENS, PROMPT_P = [8, 16], [0.6, 0.4]
    GEN_LENS, GEN_P = [8, 16, 32], [0.5, 0.3, 0.2]
    BLOCK_SIZE = 8
else:
    N_REQ = 16
    PROMPT_LENS, PROMPT_P = [16, 32, 64], [0.5, 0.3, 0.2]
    GEN_LENS, GEN_P = [16, 32, 64, 128, 256], [0.35, 0.3, 0.2, 0.1, 0.05]
    BLOCK_SIZE = 16


def make_trace(vocab: int, seed: int = 0) -> list[Request]:
    """Deterministic skewed-length trace (lengths 16..256 in the full
    grid). Distinct prompt lengths are drawn from a small set so the
    admit-path trace count stays bounded."""
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(N_REQ):
        s0 = int(rng.choice(PROMPT_LENS, p=PROMPT_P))
        max_new = int(rng.choice(GEN_LENS, p=GEN_P))
        prompt = rng.integers(0, vocab, size=s0).astype(np.int32)
        reqs.append(Request(uid=uid, prompt=prompt, max_new=max_new))
    return reqs


def run_fixed_slot(eng: GenerationEngine, reqs) -> float:
    """Arrival-order batches of CONCURRENCY; prompts pad to the batch max,
    every slot decodes to the batch-max max_new. Returns elapsed seconds."""
    t0 = time.time()
    for i in range(0, len(reqs), CONCURRENCY):
        batch = reqs[i:i + CONCURRENCY]
        s_max = max(r.prompt.size for r in batch)
        prompts = np.zeros((len(batch), s_max), np.int32)
        for j, r in enumerate(batch):
            prompts[j, :r.prompt.size] = r.prompt
        eng.generate(prompts, max(r.max_new for r in batch))
    return time.time() - t0


def make_paged_engine(params, cfg, reqs, kv_dtype: str = "act") -> PagedEngine:
    max_pages = max(
        -(-(r.prompt.size + r.max_new - 1) // BLOCK_SIZE) for r in reqs)
    return PagedEngine(
        params, cfg,
        PagedConfig(block_size=BLOCK_SIZE,
                    num_blocks=CONCURRENCY * max_pages,
                    max_concurrency=CONCURRENCY,
                    max_pages_per_seq=max_pages,
                    kv_dtype=kv_dtype),
        SamplerConfig(temperature=0.0),
    )


def hbm_accounting(cfg, reqs, num_blocks: int, kv_dtype: str = "act") -> dict:
    """Bytes of attention KV state: dense slab vs page pool (the
    docs/serving_scheduler.md formula; int8 pools count their codes at one
    byte plus the per-(page, head) scale leaves)."""
    from repro.serving.scheduler import kv_pool_bytes

    n_attn = sum(1 for s in cfg.pattern if s.mixer == "attn") * cfg.repeats
    per_pos = 2 * cfg.n_kv_heads * cfg.head_dim * np.dtype(cfg.act_dtype).itemsize
    s_max = max(r.prompt.size for r in reqs) + max(r.max_new for r in reqs)
    dense = n_attn * CONCURRENCY * s_max * per_pos
    paged = kv_pool_bytes(cfg, num_blocks, BLOCK_SIZE, kv_dtype)
    return {"dense_slab_bytes": int(dense), "paged_pool_bytes": int(paged),
            "pool_over_slab": paged / dense}


def run():
    cfg = get_config(ARCH)
    params = init_model(jax.random.key(0), cfg)
    reqs = make_trace(cfg.vocab)
    useful = sum(r.max_new for r in reqs)

    # warm every jit bucket outside the timed region, then take the best
    # of REPS timed passes per engine (host-side scheduling makes single
    # CPU wall-clock passes noisy)
    reps = 3 if FAST else 5
    fixed = GenerationEngine(params, cfg, SamplerConfig(temperature=0.0))
    run_fixed_slot(fixed, reqs)
    dt_fixed = min(run_fixed_slot(fixed, reqs) for _ in range(reps))
    eng = make_paged_engine(params, cfg, reqs)
    eng.serve(reqs)

    def paged_pass():
        t0 = time.time()
        eng.serve(make_trace(cfg.vocab))  # same-shape trace, warm buckets
        return time.time() - t0

    dt_paged = min(paged_pass() for _ in range(reps))

    # int8-KV grid: same trace, quantized pages (pool HBM ~halves for
    # bf16 serving dtypes; on the f32 tiny configs it quarters)
    eng8 = make_paged_engine(params, cfg, reqs, kv_dtype="int8")
    eng8.serve(reqs)

    def paged8_pass():
        t0 = time.time()
        eng8.serve(make_trace(cfg.vocab))
        return time.time() - t0

    dt_paged8 = min(paged8_pass() for _ in range(reps))

    fixed_toks = useful / dt_fixed
    paged_toks = useful / dt_paged
    paged8_toks = useful / dt_paged8
    speedup = paged_toks / fixed_toks
    results = {
        "backend": jax.default_backend(),
        "arch": ARCH,
        "concurrency": CONCURRENCY,
        "block_size": BLOCK_SIZE,
        "n_requests": N_REQ,
        "useful_tokens": useful,
        "prompt_lens": PROMPT_LENS,
        "gen_lens": GEN_LENS,
        "fixed_toks": fixed_toks,
        "paged_toks": paged_toks,
        "speedup": speedup,
        "us_per_tok_fixed": 1e6 * dt_fixed / useful,
        "us_per_tok_paged": 1e6 * dt_paged / useful,
        "hbm": hbm_accounting(cfg, reqs, eng.paged.num_blocks),
        "int8_kv": {
            "attn_datapath": eng8.attn_spec.describe(),
            "paged_toks": paged8_toks,
            "us_per_tok_paged": 1e6 * dt_paged8 / useful,
            "speedup_vs_float_kv": paged8_toks / paged_toks,
            "hbm": hbm_accounting(cfg, reqs, eng8.paged.num_blocks,
                                  kv_dtype="int8"),
        },
    }
    csv_row(f"serving/trace/{'fast' if FAST else 'full'}", results["us_per_tok_paged"],
            f"paged={paged_toks:.1f}toks;fixed={fixed_toks:.1f}toks;"
            f"speedup={speedup:.2f}x;"
            f"int8kv={paged8_toks:.1f}toks@"
            f"{results['int8_kv']['hbm']['pool_over_slab']:.2f}pool")
    write_bench_json("BENCH_serving.json", results)
    return results


if __name__ == "__main__":
    run()
