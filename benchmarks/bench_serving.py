"""Serving throughput under a skewed-length request trace: fixed-slot
batching vs paged-KV continuous batching.

The trace models production traffic: request lengths drawn from a skewed
distribution (most sequences short, a heavy tail long — the shape that
motivated paged attention in production servers). The fixed-slot baseline
processes the trace in arrival-order batches of ``CONCURRENCY``: prompts
pad to the batch max and every slot decodes until the batch's *longest*
request finishes — the slot-idling pathology. The paged engine runs the
same trace through the continuous-batching scheduler: a finished sequence
frees its pages and its slot is refilled mid-flight.

Throughput counts *useful* tokens only (each request's own max_new), so
the fixed-slot engine gets no credit for decoding padding slots.

A second, multi-tenant trace models the prompt-cache workload: a handful
of shared block-aligned system prompts fan out into many short
completions, so prefill dominates and the prefix cache's shared-prefix /
fully-cached admits remove most of the work. That grid runs on briefly
*trained* params (``benchmarks.common.trained_params``) so the
bit-identity assertion between the cold and warm engines is structural
rather than argmax seed luck, and reports ``prefix_cache.hit_rate`` and
``prefix_cache.speedup_vs_cold``. Writes ``BENCH_serving.json``; the CI
regression gate (scripts/bench_compare.py) tracks the tok/s numbers, the
speedups and the hit rate.
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs import get_config
from repro.models.transformer import init_model
from repro.serving import (
    GenerationEngine,
    PagedConfig,
    PagedEngine,
    Request,
    SamplerConfig,
    SchedulerPolicy,
    ServeMetrics,
)

from .common import (
    FAST,
    csv_row,
    poisson_trace,
    trace_digest,
    trained_params,
    write_bench_json,
)

import jax

ARCH = "tiny-lm-xs"
CONCURRENCY = 8
if FAST:
    N_REQ = 8
    PROMPT_LENS, PROMPT_P = [8, 16], [0.6, 0.4]
    GEN_LENS, GEN_P = [8, 16, 32], [0.5, 0.3, 0.2]
    BLOCK_SIZE = 8
else:
    N_REQ = 16
    PROMPT_LENS, PROMPT_P = [16, 32, 64], [0.5, 0.3, 0.2]
    GEN_LENS, GEN_P = [16, 32, 64, 128, 256], [0.35, 0.3, 0.2, 0.1, 0.05]
    BLOCK_SIZE = 16
# multi-tenant grid: N_SYSTEMS shared block-aligned system prompts of
# SYS_BLOCKS pages each fanning out into MT_N_REQ short completions —
# deep systems + few new tokens keep the trace prefill-dominated, which
# is the regime the prefix cache removes work from
N_SYSTEMS = 2
SYS_BLOCKS = 8
MT_N_REQ = 16 if FAST else 32
MT_MAX_NEW = 4
# tail-latency grid: a seeded Poisson burst (benchmarks.common.
# poisson_trace — byte-for-byte reproducible, digest recorded) served by
# the legacy FIFO policy vs the throughput policy at EQUAL pool size.
# Most prompts are short with a heavy long tail — under FIFO the burst
# is admitted one B=1 prefill at a time and the long prompt stalls
# everything behind it; the throughput policy co-admits the shorts in
# batched prefill programs and chunks the long prompt between decode
# chunks, which is exactly what the p99 TTFT gate measures. The burst
# size equals the slot count: with more arrivals than slots the tail is
# *completion*-bound (a slot must free) identically under both policies,
# which would measure decode speed, not admission — the admission-path
# win this grid exists to gate. The policy carries no watermark: growth
# is one device dispatch per page crossing, pure overhead when the pool
# already fits every worst case (watermark + preemption are exercised
# under genuine pool pressure in tests/test_scheduler.py and
# tests/test_paged_engine.py instead).
LAT_N = 8 if FAST else 24
LAT_CONC = LAT_N
LAT_RATE = 2000.0  # req/s: a burst relative to tiny-model service time
LAT_PROMPTS = ([8, 8, 8, 16, 16, 48] if FAST
               else [16, 16, 16, 32, 32, 96])  # repeats encode the skew
LAT_MAX_NEWS = [4, 8, 8, 16] if FAST else [8, 16, 16, 32]
LAT_PRIORITIES = (0, 0, 1)  # two classes, interactive-heavy
LAT_SEED = 13 if FAST else 62
LAT_CHUNK_MAX = 8 if FAST else 2  # bounds decode-interleave delay between prefill chunks
LAT_POLICY = SchedulerPolicy(admit_window=4 if FAST else 8,
                             batch_max=4 if FAST else 8,
                             prefill_chunk=4 * BLOCK_SIZE)


def make_trace(vocab: int, seed: int = 0) -> list[Request]:
    """Deterministic skewed-length trace (lengths 16..256 in the full
    grid). Distinct prompt lengths are drawn from a small set so the
    admit-path trace count stays bounded."""
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(N_REQ):
        s0 = int(rng.choice(PROMPT_LENS, p=PROMPT_P))
        max_new = int(rng.choice(GEN_LENS, p=GEN_P))
        prompt = rng.integers(0, vocab, size=s0).astype(np.int32)
        reqs.append(Request(uid=uid, prompt=prompt, max_new=max_new))
    return reqs


def run_fixed_slot(eng: GenerationEngine, reqs) -> float:
    """Arrival-order batches of CONCURRENCY; prompts pad to the batch max,
    every slot decodes to the batch-max max_new. Returns elapsed seconds."""
    t0 = time.time()
    for i in range(0, len(reqs), CONCURRENCY):
        batch = reqs[i:i + CONCURRENCY]
        s_max = max(r.prompt.size for r in batch)
        prompts = np.zeros((len(batch), s_max), np.int32)
        for j, r in enumerate(batch):
            prompts[j, :r.prompt.size] = r.prompt
        eng.generate(prompts, max(r.max_new for r in batch))
    return time.time() - t0


def make_paged_engine(params, cfg, reqs, kv_dtype: str = "act",
                      prefix_cache: bool = False,
                      policy: SchedulerPolicy = SchedulerPolicy(),
                      concurrency: int = CONCURRENCY,
                      chunk_max: int | None = None) -> PagedEngine:
    max_pages = max(
        -(-(r.prompt.size + r.max_new - 1) // BLOCK_SIZE) for r in reqs)
    kw = {} if chunk_max is None else {"chunk_max": chunk_max}
    return PagedEngine(
        params, cfg,
        PagedConfig(block_size=BLOCK_SIZE,
                    num_blocks=concurrency * max_pages,
                    max_concurrency=concurrency,
                    max_pages_per_seq=max_pages,
                    kv_dtype=kv_dtype,
                    prefix_cache=prefix_cache,
                    sched=policy,
                    **kw),
        SamplerConfig(temperature=0.0),
    )


def make_multitenant_trace(vocab: int, seed: int = 1) -> list[Request]:
    """N_SYSTEMS shared block-aligned system prompts x MT_N_REQ short
    completions; a zero-length tail on a block-aligned prompt exercises
    the fully-cached (zero-prefill) admit."""
    rng = np.random.default_rng(seed)
    systems = [rng.integers(0, vocab, size=SYS_BLOCKS * BLOCK_SIZE)
               .astype(np.int32) for _ in range(N_SYSTEMS)]
    reqs = []
    for uid in range(MT_N_REQ):
        tail = rng.integers(0, vocab,
                            size=int(rng.integers(0, BLOCK_SIZE))
                            ).astype(np.int32)
        reqs.append(Request(
            uid=uid, prompt=np.concatenate([systems[uid % N_SYSTEMS], tail]),
            max_new=MT_MAX_NEW))
    return reqs


def run_multitenant(params, cfg, kv_dtype: str, reps: int) -> dict:
    """Cold engine vs prefix-cache engine over the multi-tenant trace.
    Greedy outputs must be bit-identical before any speedup is reported
    (first warm pass and steady state alike); the timed warm passes run
    against the populated cache, so ``speedup_vs_cold`` is the steady-
    state prompt-cache win."""
    trace = make_multitenant_trace(cfg.vocab)
    useful = sum(r.max_new for r in trace)

    def timed(eng):
        best, res = float("inf"), None
        for _ in range(reps):
            t0 = time.time()
            out = eng.serve(trace)
            dt = time.time() - t0
            if dt < best:
                best, res = dt, out
        return best, res

    cold = make_paged_engine(params, cfg, trace, kv_dtype=kv_dtype)
    ref = cold.serve(trace)  # warm the jit buckets
    dt_cold, _ = timed(cold)
    warm = make_paged_engine(params, cfg, trace, kv_dtype=kv_dtype,
                             prefix_cache=True)
    first = warm.serve(trace)  # populate the cache + warm the buckets
    dt_warm, steady = timed(warm)
    for r in trace:
        np.testing.assert_array_equal(first[r.uid], ref[r.uid])
        np.testing.assert_array_equal(steady[r.uid], ref[r.uid])
    stats = warm.prefix_cache.stats()
    return {
        "hit_rate": stats["hit_rate"],
        "hits": stats["hits"],
        "lookups": stats["lookups"],
        "cold_toks": useful / dt_cold,
        "warm_toks": useful / dt_warm,
        "speedup_vs_cold": dt_cold / dt_warm,
    }


def run_latency(params, cfg, reps: int) -> dict:
    """Tail-latency grid: the Poisson burst through the legacy FIFO
    policy vs the throughput policy at equal pool size. Greedy outputs
    are asserted bit-identical between the two engines on every pass
    before any latency number is reported; percentiles take the
    elementwise min-over-reps envelope (same estimator as ``time_min`` —
    scheduler noise only ever makes a pass slower)."""
    raw, arrivals = poisson_trace(
        LAT_N, LAT_RATE, LAT_SEED, prompt_lens=LAT_PROMPTS,
        max_news=LAT_MAX_NEWS, priorities=LAT_PRIORITIES, vocab=cfg.vocab)
    useful = sum(r["max_new"] for r in raw)

    def mk_reqs():
        return [Request(**r) for r in raw]

    def timed(eng):
        """reps+1 passes (first warms the jit buckets); returns the
        min-envelope metric summary, best tokens/s, and the outputs."""
        best_dt, out, env = float("inf"), None, {}
        for i in range(reps + 1):
            m = ServeMetrics()
            t0 = time.time()
            res = eng.serve(mk_reqs(), arrivals=arrivals, metrics=m)
            dt = time.time() - t0
            if i == 0:
                out = res
                continue  # warm pass: compiles excluded from the envelope
            for r in raw:
                np.testing.assert_array_equal(res[r["uid"]], out[r["uid"]])
            best_dt = min(best_dt, dt)
            for k, v in m.summary().items():
                if isinstance(v, dict):
                    sec = env.setdefault(k, {})
                    for kk, vv in v.items():
                        sec[kk] = min(sec.get(kk, vv), vv) \
                            if kk.endswith("_us") else vv
                else:
                    env[k] = min(env.get(k, v), v) if k.endswith("_us") else v
        return env, useful / best_dt, out

    reqs = mk_reqs()
    fifo = make_paged_engine(params, cfg, reqs, concurrency=LAT_CONC,
                             chunk_max=LAT_CHUNK_MAX)
    thr = make_paged_engine(params, cfg, reqs, policy=LAT_POLICY,
                            concurrency=LAT_CONC, chunk_max=LAT_CHUNK_MAX)
    fifo_m, fifo_toks, fifo_out = timed(fifo)
    thr_m, thr_toks, thr_out = timed(thr)
    for r in raw:  # the acceptance identity: FIFO vs throughput engine
        np.testing.assert_array_equal(thr_out[r["uid"]], fifo_out[r["uid"]])
    return {
        "n_requests": LAT_N,
        "rate_rps": LAT_RATE,
        "concurrency": LAT_CONC,
        "trace_digest": trace_digest(raw, arrivals),
        "policy": {"admit_window": LAT_POLICY.admit_window,
                   "batch_max": LAT_POLICY.batch_max,
                   "prefill_chunk": LAT_POLICY.prefill_chunk,
                   "watermark": (None if LAT_POLICY.watermark is None
                                 else list(LAT_POLICY.watermark))},
        "fifo": fifo_m,
        "throughput": thr_m,
        "fifo_toks": fifo_toks,
        "throughput_toks": thr_toks,
        "toks_ratio_vs_fifo": thr_toks / fifo_toks,
        "ttft_p50_speedup_vs_fifo":
            fifo_m["ttft_p50_us"] / thr_m["ttft_p50_us"],
        "ttft_p99_speedup_vs_fifo":
            fifo_m["ttft_p99_us"] / thr_m["ttft_p99_us"],
        "n_preemptions": thr_m["n_preemptions"],
        "batch_traces": thr.batch_traces,
        "prefill_chunk_traces": thr.prefill_chunk_traces,
    }


def hbm_accounting(cfg, reqs, num_blocks: int, kv_dtype: str = "act") -> dict:
    """Bytes of attention KV state: dense slab vs page pool (the
    docs/serving_scheduler.md formula; int8 pools count their codes at one
    byte plus the per-(page, head) scale leaves)."""
    from repro.serving.scheduler import kv_pool_bytes

    n_attn = sum(1 for s in cfg.pattern if s.mixer == "attn") * cfg.repeats
    per_pos = 2 * cfg.n_kv_heads * cfg.head_dim * np.dtype(cfg.act_dtype).itemsize
    s_max = max(r.prompt.size for r in reqs) + max(r.max_new for r in reqs)
    dense = n_attn * CONCURRENCY * s_max * per_pos
    paged = kv_pool_bytes(cfg, num_blocks, BLOCK_SIZE, kv_dtype)
    return {"dense_slab_bytes": int(dense), "paged_pool_bytes": int(paged),
            "pool_over_slab": paged / dense}


def run():
    cfg = get_config(ARCH)
    params = init_model(jax.random.key(0), cfg)
    reqs = make_trace(cfg.vocab)
    useful = sum(r.max_new for r in reqs)

    # warm every jit bucket outside the timed region, then take the best
    # of REPS timed passes per engine (host-side scheduling makes single
    # CPU wall-clock passes noisy)
    reps = 3 if FAST else 5
    fixed = GenerationEngine(params, cfg, SamplerConfig(temperature=0.0))
    run_fixed_slot(fixed, reqs)
    dt_fixed = min(run_fixed_slot(fixed, reqs) for _ in range(reps))
    eng = make_paged_engine(params, cfg, reqs)
    eng.serve(reqs)

    def paged_pass():
        t0 = time.time()
        eng.serve(make_trace(cfg.vocab))  # same-shape trace, warm buckets
        return time.time() - t0

    dt_paged = min(paged_pass() for _ in range(reps))

    # int8-KV grid: same trace, quantized pages (pool HBM ~halves for
    # bf16 serving dtypes; on the f32 tiny configs it quarters)
    eng8 = make_paged_engine(params, cfg, reqs, kv_dtype="int8")
    eng8.serve(reqs)

    def paged8_pass():
        t0 = time.time()
        eng8.serve(make_trace(cfg.vocab))
        return time.time() - t0

    dt_paged8 = min(paged8_pass() for _ in range(reps))

    # multi-tenant prompt-cache grid on briefly trained params (greedy
    # bit-identity cold-vs-warm is asserted inside, float and int8 KV)
    mt_cfg, mt_params = trained_params(ARCH)
    prefix = run_multitenant(mt_params, mt_cfg, "act", reps)
    prefix["int8"] = run_multitenant(mt_params, mt_cfg, "int8", reps)

    # tail-latency grid (trained params: the FIFO-vs-throughput greedy
    # identity asserted inside is structural, not argmax seed luck)
    latency = run_latency(mt_params, mt_cfg, reps)

    fixed_toks = useful / dt_fixed
    paged_toks = useful / dt_paged
    paged8_toks = useful / dt_paged8
    speedup = paged_toks / fixed_toks
    results = {
        "backend": jax.default_backend(),
        "arch": ARCH,
        "concurrency": CONCURRENCY,
        "block_size": BLOCK_SIZE,
        "n_requests": N_REQ,
        "useful_tokens": useful,
        "prompt_lens": PROMPT_LENS,
        "gen_lens": GEN_LENS,
        "fixed_toks": fixed_toks,
        "paged_toks": paged_toks,
        "speedup": speedup,
        "us_per_tok_fixed": 1e6 * dt_fixed / useful,
        "us_per_tok_paged": 1e6 * dt_paged / useful,
        "hbm": hbm_accounting(cfg, reqs, eng.paged.num_blocks),
        "int8_kv": {
            "attn_datapath": eng8.attn_spec.describe(),
            "paged_toks": paged8_toks,
            "us_per_tok_paged": 1e6 * dt_paged8 / useful,
            "speedup_vs_float_kv": paged8_toks / paged_toks,
            "hbm": hbm_accounting(cfg, reqs, eng8.paged.num_blocks,
                                  kv_dtype="int8"),
        },
        "prefix_cache": prefix,
        "latency": latency,
    }
    csv_row(f"serving/trace/{'fast' if FAST else 'full'}", results["us_per_tok_paged"],
            f"paged={paged_toks:.1f}toks;fixed={fixed_toks:.1f}toks;"
            f"speedup={speedup:.2f}x;"
            f"int8kv={paged8_toks:.1f}toks@"
            f"{results['int8_kv']['hbm']['pool_over_slab']:.2f}pool;"
            f"pc={prefix['speedup_vs_cold']:.2f}x@"
            f"{prefix['hit_rate']:.2f}hr;"
            f"ttft_p99={latency['ttft_p99_speedup_vs_fifo']:.2f}x@"
            f"{latency['toks_ratio_vs_fifo']:.2f}toks")
    write_bench_json("BENCH_serving.json", results)
    return results


if __name__ == "__main__":
    run()
