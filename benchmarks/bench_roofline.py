"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads the JSON records produced by ``repro.launch.dryrun --out results/dryrun``
and prints the (arch x shape) table: three terms, dominant bottleneck,
useful-FLOPs ratio, roofline fraction."""

from __future__ import annotations

import glob
import json
import os

from .common import csv_row

DEFAULT_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "dryrun",
)


def run(results_dir: str | None = None):
    d = results_dir or DEFAULT_DIR
    files = sorted(glob.glob(os.path.join(d, "*.json")))
    if not files:
        csv_row("roofline/none", 0.0,
                f"no dry-run artifacts under {d}; run repro.launch.dryrun --all")
        return []
    rows = []
    for f in files:
        rec = json.load(open(f))
        tag = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec.get("status") != "ok":
            csv_row(f"roofline/{tag}", 0.0, f"FAIL:{rec.get('error', '?')[:80]}")
            continue
        rl = rec["roofline"]
        bound_us = max(rl["compute_s"], rl["memory_s"], rl["collective_s"]) * 1e6
        csv_row(
            f"roofline/{tag}",
            bound_us,
            f"compute_s={rl['compute_s']:.3e};memory_s={rl['memory_s']:.3e};"
            f"collective_s={rl['collective_s']:.3e};dominant={rl['dominant']};"
            f"useful_ratio={rl['useful_flops_ratio']:.3f};"
            f"roofline_frac={rl['roofline_fraction']:.4f};"
            f"bytes_per_dev={rec['memory']['peak_bytes_per_device']:.3e}",
        )
        rows.append(rec)
    return rows


if __name__ == "__main__":
    run()
