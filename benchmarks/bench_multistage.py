"""Paper Table 1: multi-stage accumulation at scale — W4A8, 16-bit inner
accumulator, tiles T in {64, 128}, across the width ladder (the paper's
Pythia suite becomes the tiny-lm ladder; the *scaling trend* — constrained
quality approaching the unconstrained Base as width grows — is the claim
under test)."""

from __future__ import annotations

from repro.core import PTQConfig

from .common import (
    FAST,
    baseline_float_ppl,
    calib_batches,
    csv_row,
    eval_batches,
    quantize_and_eval,
    trained_params,
)

LADDER = ["tiny-lm-xs", "tiny-lm-s", "tiny-lm-m", "tiny-lm-l"]
if FAST:
    LADDER = ["tiny-lm-xs", "tiny-lm-s"]
TILES = (64, 128)


def run(algorithms=("gpfq", "optq")):
    results = {}
    for arch in LADDER:
        cfg, params = trained_params(arch)
        calib = calib_batches(cfg)
        evalb = eval_batches(cfg)
        fppl = baseline_float_ppl(cfg, params, evalb)
        csv_row(f"table1/{arch}/float", 0.0, f"ppl={fppl:.2f}")
        for alg in algorithms:
            base = quantize_and_eval(
                cfg, params, PTQConfig(algorithm=alg, constrain=False),
                calib, evalb,
            )
            results[(arch, alg, "base")] = base["ppl"]
            csv_row(f"table1/{arch}/{alg}/base", base["quantize_s"] * 1e6,
                    f"ppl={base['ppl']:.2f}")
            for t in TILES:
                res = quantize_and_eval(
                    cfg, params,
                    PTQConfig(algorithm=alg, p_bits=16, tile=t),
                    calib, evalb,
                )
                results[(arch, alg, f"{t}x16b")] = res["ppl"]
                csv_row(
                    f"table1/{arch}/{alg}/{t}x16b",
                    res["quantize_s"] * 1e6,
                    f"ppl={res['ppl']:.2f};cert={res['certified']};"
                    f"gap_vs_base={res['ppl'] - base['ppl']:+.2f}",
                )
    return results


if __name__ == "__main__":
    run()
