"""Kernel micro-benchmarks: wall time per call (interpret mode on CPU — the
number that matters on this box is the *derived* analytic intensity; the
TPU timing comes from the roofline terms in EXPERIMENTS.md §Roofline)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import norm_and_quantize, pack_int4, w4a8_matmul

from .common import csv_row


def _time(fn, *args, reps=3, **kw):
    y = fn(*args, **kw)
    jax.block_until_ready(y)
    t0 = time.time()
    for _ in range(reps):
        y = fn(*args, **kw)
    jax.block_until_ready(y)
    return (time.time() - t0) / reps * 1e6


def run():
    rng = np.random.default_rng(0)
    for m, k, n in [(128, 512, 256), (256, 1024, 512)]:
        q = rng.integers(-7, 8, size=(k, n))
        wp = pack_int4(jnp.asarray(q))
        x = jnp.asarray(rng.integers(0, 256, size=(m, k)), jnp.uint8)
        sc = jnp.ones((n,), jnp.float32)
        us = _time(w4a8_matmul, x, wp, sc, 0.02, 128, interpret=True,
                   block_m=min(m, 128), block_n=128, block_k=128)
        flops = 2 * m * k * n
        # HBM bytes on the TPU target: uint8 acts + packed int4 weights + f32 out
        bytes_hbm = m * k + k * n // 2 + m * n * 4
        csv_row(
            f"kernel/w4a8_mm/{m}x{k}x{n}", us,
            f"flops={flops};hbm_bytes={bytes_hbm};"
            f"intensity={flops / bytes_hbm:.1f}flop/B;"
            f"v5e_bound={'compute' if flops / bytes_hbm > 197e12 / 819e9 else 'memory'}",
        )

    for m, d in [(512, 1024), (1024, 4096)]:
        x = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
        g = jnp.ones((d,), jnp.float32)
        us = _time(norm_and_quantize, x, g, 0.02, 128, interpret=True,
                   block_m=256)
        bytes_hbm = m * d * 4 + m * d  # read f32, write u8
        csv_row(f"kernel/quant_rmsnorm/{m}x{d}", us,
                f"hbm_bytes={bytes_hbm};write_savings=4x_vs_f32")
    return None


if __name__ == "__main__":
    run()
