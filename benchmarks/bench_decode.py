"""Device-resident decode throughput: prefill and decode tok/s for the
float baseline vs the packed-dequant fallback vs the fused W4A8 kernel
datapath, through the real GenerationEngine (fused on-device loop).

Three comparisons per arch:

  * engine-level prefill + decode tok/s, float vs packed params — on this
    CPU box the packed path runs the in-graph dequant fallback; on TPU the
    same call rides the Pallas kernel (backend "auto");
  * host-loop vs fused-loop decode tok/s (the loop-overhead term the
    on-device while_loop removes);
  * site-level us/call for one decode-shaped matmul, dequant vs fused
    kernel (interpret mode on CPU — a *validity* probe, not a speed claim;
    compiled-kernel timing only means anything on TPU hardware).

Writes ``BENCH_decode.json`` (cwd) so the perf trajectory is tracked
from this PR onward, and prints the usual csv rows.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels.ops import quantize_activations
from repro.kernels.w4a8_mm import w4a8_decode_matmul
from repro.models.layers import dequant_weight
from repro.models.transformer import init_model
from repro.quant.serve_packed import _pack_leaf, pack_decode_params
from repro.serving import GenerationEngine, SamplerConfig

from .common import FAST, csv_row, time_min, write_bench_json

ARCHS = ["tiny-lm-xs"] if FAST else ["tiny-lm-xs", "tiny-lm-s"]
BATCH = 2 if FAST else 4
PROMPT = 8 if FAST else 32
NEW = 8 if FAST else 32
SITE_K, SITE_N = (128, 128) if FAST else (512, 512)


def _time(fn, reps: int = 5) -> float:
    return time_min(fn, reps)


def _engine_toks(gen, prompts, max_new) -> float:
    dt = _time(lambda: gen(prompts, max_new), reps=5)
    return prompts.shape[0] * max_new / dt


def _site_bench() -> dict:
    """One decode-shaped (B, K) x (K, N) site: dequant vs fused kernel."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(SITE_K, SITE_N)), jnp.float32)
    leaf = _pack_leaf(w)
    x = jnp.asarray(rng.normal(size=(BATCH, SITE_K)), jnp.float32)

    @jax.jit
    def dequant_mm(x, leaf):
        return x @ dequant_weight(leaf)

    @jax.jit
    def kernel_mm(x, leaf):
        codes, s, zp = quantize_activations(x)
        return w4a8_decode_matmul(
            codes, leaf["packed"], leaf["scale"].reshape(-1),
            leaf["col_sums"].reshape(-1), s, zp,
            interpret=jax.default_backend() != "tpu",
        )

    us_dequant = _time(lambda: jax.block_until_ready(dequant_mm(x, leaf))) * 1e6
    us_kernel = _time(lambda: jax.block_until_ready(kernel_mm(x, leaf))) * 1e6
    err = float(jnp.max(jnp.abs(dequant_mm(x, leaf) - kernel_mm(x, leaf))))
    return {"us_dequant": us_dequant, "us_kernel": us_kernel, "max_abs_err": err}


def run():
    results = {"backend": jax.default_backend(), "archs": {}}
    for arch in ARCHS:
        cfg = get_config(arch)
        params = init_model(jax.random.key(0), cfg)
        pparams = pack_decode_params(params, cfg)
        prompts = np.asarray(
            jax.random.randint(jax.random.key(1), (BATCH, PROMPT), 0, cfg.vocab),
            np.int32,
        )
        samp = SamplerConfig(temperature=0.0)
        ef = GenerationEngine(params, cfg, samp)
        ep = GenerationEngine(pparams, cfg, samp)

        row = {
            "float_fused_toks": _engine_toks(ef.generate, prompts, NEW),
            "float_host_toks": _engine_toks(ef.generate_host_loop, prompts, NEW),
            "packed_fused_toks": _engine_toks(ep.generate, prompts, NEW),
        }
        results["archs"][arch] = row
        csv_row(
            f"decode/{arch}/engine",
            1e6 * BATCH * NEW / row["packed_fused_toks"],
            f"float_fused={row['float_fused_toks']:.1f}toks;"
            f"float_host={row['float_host_toks']:.1f}toks;"
            f"packed_fused={row['packed_fused_toks']:.1f}toks",
        )

    site = _site_bench()
    results["site"] = site
    csv_row(
        "decode/site/w4a8",
        site["us_kernel"],
        f"dequant_us={site['us_dequant']:.1f};kernel_us={site['us_kernel']:.1f};"
        f"max_abs_err={site['max_abs_err']:.4f}",
    )
    write_bench_json("BENCH_decode.json", results)
    return results


if __name__ == "__main__":
    run()
